//! Agent-to-agent message transport.
//!
//! The framework runs in two deployment modes:
//!
//! * **In-process** ([`InProcNetwork`]) — every agent is a thread in one OS
//!   process; messages travel over `std::sync::mpsc` channels.  This is the
//!   default for tests, benches and single-machine studies.
//! * **TCP** ([`TcpTransport`]) — agents are separate OS processes
//!   (possibly on different hosts); messages are length-prefixed JSON
//!   frames over persistent sockets.  Payloads must implement [`Wire`].
//!
//! Both implement [`Transport`], so the engine/agent layers are agnostic.
//! Channels are FIFO per (src, dst) pair — the property the conservative
//! protocol relies on (a channel's head timestamp bounds the channel).
//!
//! ## Window-batched frame schema
//!
//! Safe-window execution flushes an engine's outbox once per window, so the
//! wire protocol batches at the same granularity: a flush produces **one
//! [`NetMsg::WindowBatch`] frame per destination peer**, carrying every
//! event of the window bound for that peer (in emission order), the
//! window's sync messages for that peer, and a single piggybacked promise
//! (`bound`) applied *after* the frame's events — plus at most **one
//! [`ControlMsg::WindowReport`] frame to the leader** carrying the window's
//! published result records and the sender's cumulative executed-window
//! count (the leader's GVT progress signal).  Frames per window are
//! therefore O(peers), not O(messages).
//!
//! The atomic frame is what makes the single trailing `bound` sound: the
//! receiver ingests the frame's events before observing the promise, and
//! every *future* send to that peer is ≥ the post-drain bound by the same
//! argument that justifies [`LvtAnnounce`](crate::engine::SyncMsg)
//! bounds.  A `WindowBatch` whose encoding exceeds the frame-size limit is
//! split transparently; non-final chunks carry no sync flush and no bound,
//! so promise ordering survives the split.
//!
//! The pre-batch frames (`event`, `sync`, one frame per message) remain
//! fully supported: they are still emitted when wire batching is disabled
//! (`deploy.wire_batch = false`) and always decode, so mixed old/new
//! fleets interoperate.
//!
//! Frames are length-prefixed (u32, big-endian) and capped at a
//! configurable limit ([`DEFAULT_MAX_FRAME_BYTES`]); an inbound oversized
//! frame is drained and skipped — one bad frame never poisons its reader
//! thread or connection.

use std::collections::HashMap;
use std::io::{Read, Write as IoWrite};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::engine::{Event, SimTime, SyncMsg};
use crate::util::json::Json;
use crate::util::{AgentId, ContextId, LpId};

// ---------------------------------------------------------------------------
// Messages
// ---------------------------------------------------------------------------

/// Control-plane messages (deployment, termination detection, monitoring).
#[derive(Clone, Debug, PartialEq)]
pub enum ControlMsg {
    /// Leader -> agent: install an LP of `kind` with JSON params.
    DeployLp {
        context: ContextId,
        lp: LpId,
        kind: String,
        params: Json,
    },
    /// Leader -> agent: full LP->agent routing table for a context.
    RoutingTable {
        context: ContextId,
        routes: Vec<(LpId, AgentId)>,
    },
    /// Leader -> agent: inject a bootstrap event.
    Bootstrap {
        context: ContextId,
        time: SimTime,
        dst: LpId,
        payload: Json,
    },
    /// Leader -> agent: begin executing a context.  `participants` is the
    /// set of agents actually hosting LPs of this context — only they take
    /// part in conservative synchronization (a fleet member with no LPs
    /// would otherwise be dead weight the demand protocol keeps polling).
    StartRun {
        context: ContextId,
        participants: Vec<AgentId>,
    },
    /// Termination detection probe (double-count algorithm).
    Probe { context: ContextId, round: u64 },
    /// Agent -> leader: probe answer (idle?, #sent, #received, lvt,
    /// earliest pending event, safe windows executed).
    ProbeReply {
        context: ContextId,
        round: u64,
        from: AgentId,
        idle: bool,
        sent: u64,
        received: u64,
        lvt: SimTime,
        next_event: SimTime,
        /// Total safe windows this agent has executed for the context —
        /// the termination detector's progress signal at window
        /// granularity.
        windows: u64,
    },
    /// Leader -> agents: proven GVT lower bound (quiescent probe round).
    GvtUpdate { context: ContextId, gvt: SimTime },
    /// Leader -> agents: context finished; tear down and report stats.
    EndRun { context: ContextId },
    /// Agent -> leader: final per-agent statistics (JSON-encoded).
    FinalStats {
        context: ContextId,
        from: AgentId,
        stats: Json,
    },
    /// Agent -> leader: published simulation result record (pre-batch
    /// frame; still accepted, and emitted when wire batching is off).
    Result {
        context: ContextId,
        kind: String,
        record: Json,
    },
    /// Agent -> leader, once per flushed window: every result record the
    /// window published, plus the sender's cumulative executed-window
    /// count.  Replaces one `Result` frame per record with one frame per
    /// window, and doubles as the window-completion notification that
    /// triggers leader GVT probe rounds on virtual progress.
    WindowReport {
        context: ContextId,
        from: AgentId,
        /// Total safe windows the sender has executed for the context.
        windows: u64,
        records: Vec<(String, Json)>,
    },
    /// Monitoring: an agent's published performance sample.
    PerfSample { from: AgentId, value: f64, load: Json },
    /// Graceful process shutdown (TCP mode).
    Shutdown,
}

/// Everything that can travel between agents.
#[derive(Clone, Debug)]
pub enum NetMsg<P> {
    /// A simulation event, carrying the sender's current per-destination
    /// safe bound as a piggybacked null message (classic CMB optimization:
    /// every event refreshes the receiver's LVT-queue entry for free).
    /// Pre-batch frame: still accepted, and emitted when wire batching is
    /// off.
    Event {
        context: ContextId,
        event: Event<P>,
        bound: SimTime,
    },
    /// One window's traffic to one peer in a single frame: the window's
    /// events for that peer (in emission order), its sync flush, and the
    /// sender's post-window promise.  The receiver ingests events, then
    /// sync, then the bound — so the single trailing promise can never
    /// undercut an event of its own frame.  `bound` is `None` on non-final
    /// chunks of a size-split batch.
    WindowBatch {
        context: ContextId,
        from: AgentId,
        events: Vec<Event<P>>,
        sync: Vec<SyncMsg>,
        bound: Option<SimTime>,
    },
    Sync {
        context: ContextId,
        from: AgentId,
        msg: SyncMsg,
    },
    Space(crate::space::SpaceMsg),
    Control(ControlMsg),
}

// ---------------------------------------------------------------------------
// Transport trait
// ---------------------------------------------------------------------------

/// A bidirectional, FIFO-per-channel message fabric for one agent.
pub trait Transport<P>: Send {
    /// This endpoint's agent id.
    fn me(&self) -> AgentId;

    /// All agents reachable (including self).
    fn agents(&self) -> Vec<AgentId>;

    /// Send a message to one agent.
    fn send(&self, to: AgentId, msg: NetMsg<P>) -> Result<()>;

    /// Receive the next message for this agent, waiting up to `timeout`.
    fn recv_timeout(&self, timeout: Duration) -> Option<NetMsg<P>>;

    /// Non-blocking drain of everything currently queued.
    fn drain(&self) -> Vec<NetMsg<P>> {
        let mut out = Vec::new();
        while let Some(m) = self.recv_timeout(Duration::ZERO) {
            out.push(m);
        }
        out
    }

    /// Send to every other agent.
    fn broadcast(&self, msg: NetMsg<P>) -> Result<()>
    where
        P: Clone,
    {
        for a in self.agents() {
            if a != self.me() {
                self.send(a, msg.clone())?;
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// In-process transport
// ---------------------------------------------------------------------------

struct InProcShared<P> {
    inboxes: RwLock<HashMap<AgentId, Sender<NetMsg<P>>>>,
    /// Per-sender delivery counters (message-count metrics for benches).
    sent: Mutex<HashMap<AgentId, u64>>,
}

/// Factory for a set of connected in-process endpoints.
pub struct InProcNetwork<P> {
    shared: Arc<InProcShared<P>>,
}

impl<P: Send + 'static> InProcNetwork<P> {
    pub fn new() -> Self {
        InProcNetwork {
            shared: Arc::new(InProcShared {
                inboxes: RwLock::new(HashMap::new()),
                sent: Mutex::new(HashMap::new()),
            }),
        }
    }

    /// Create the endpoint for `agent`.  Panics if the id is taken.
    pub fn endpoint(&self, agent: AgentId) -> InProcEndpoint<P> {
        let (tx, rx) = channel();
        let mut inboxes = self.shared.inboxes.write().unwrap();
        assert!(
            inboxes.insert(agent, tx).is_none(),
            "duplicate agent {agent}"
        );
        InProcEndpoint {
            me: agent,
            shared: Arc::clone(&self.shared),
            inbox: Mutex::new(rx),
        }
    }

    /// Total messages sent through the fabric (all endpoints).
    pub fn total_sent(&self) -> u64 {
        self.shared.sent.lock().unwrap().values().sum()
    }
}

impl<P: Send + 'static> Default for InProcNetwork<P> {
    fn default() -> Self {
        Self::new()
    }
}

/// One agent's endpoint on an [`InProcNetwork`].
pub struct InProcEndpoint<P> {
    me: AgentId,
    shared: Arc<InProcShared<P>>,
    inbox: Mutex<Receiver<NetMsg<P>>>,
}

impl<P: Send + 'static> Transport<P> for InProcEndpoint<P> {
    fn me(&self) -> AgentId {
        self.me
    }

    fn agents(&self) -> Vec<AgentId> {
        let mut v: Vec<AgentId> = self.shared.inboxes.read().unwrap().keys().copied().collect();
        v.sort();
        v
    }

    fn send(&self, to: AgentId, msg: NetMsg<P>) -> Result<()> {
        let inboxes = self.shared.inboxes.read().unwrap();
        let tx = inboxes
            .get(&to)
            .ok_or_else(|| anyhow!("unknown agent {to}"))?;
        tx.send(msg).map_err(|_| anyhow!("agent {to} hung up"))?;
        *self.shared.sent.lock().unwrap().entry(self.me).or_insert(0) += 1;
        Ok(())
    }

    fn recv_timeout(&self, timeout: Duration) -> Option<NetMsg<P>> {
        let rx = self.inbox.lock().unwrap();
        if timeout.is_zero() {
            rx.try_recv().ok()
        } else {
            rx.recv_timeout(timeout).ok()
        }
    }
}

// ---------------------------------------------------------------------------
// Wire encoding (TCP mode)
// ---------------------------------------------------------------------------

/// JSON-encodable payloads (needed only for the TCP transport; the
/// in-process transport moves values directly).
pub trait Wire: Sized {
    fn to_json(&self) -> Json;
    fn from_json(j: &Json) -> Result<Self>;
}

impl Wire for u32 {
    fn to_json(&self) -> Json {
        Json::num(*self as f64)
    }
    fn from_json(j: &Json) -> Result<Self> {
        j.as_u64()
            .map(|v| v as u32)
            .ok_or_else(|| anyhow!("expected number"))
    }
}

pub(crate) fn time_to_json(t: SimTime) -> Json {
    if t.0 == f64::INFINITY {
        Json::str("inf")
    } else if t.0 == f64::NEG_INFINITY {
        Json::str("-inf")
    } else {
        Json::num(t.0)
    }
}

pub(crate) fn time_from_json(j: &Json) -> Result<SimTime> {
    match j {
        Json::Num(n) => Ok(SimTime::new(*n)),
        Json::Str(s) if s == "inf" => Ok(SimTime::INF),
        Json::Str(s) if s == "-inf" => Ok(SimTime::NEG_INF),
        _ => bail!("bad time {j}"),
    }
}

fn event_to_json<P: Wire>(e: &Event<P>) -> Json {
    Json::obj(vec![
        ("t", time_to_json(e.time)),
        ("tie0", Json::num(e.tie.0 as f64)),
        ("tie1", Json::num(e.tie.1 as f64)),
        ("sa", Json::num(e.src_agent.raw() as f64)),
        ("sl", Json::num(e.src_lp.raw() as f64)),
        ("dl", Json::num(e.dst_lp.raw() as f64)),
        ("p", e.payload.to_json()),
    ])
}

fn event_from_json<P: Wire>(j: &Json) -> Result<Event<P>> {
    Ok(Event {
        time: time_from_json(j.get("t").context("t")?)?,
        tie: (
            j.get("tie0").and_then(Json::as_u64).context("tie0")?,
            j.get("tie1").and_then(Json::as_u64).context("tie1")?,
        ),
        src_agent: AgentId(j.get("sa").and_then(Json::as_u64).context("sa")?),
        src_lp: LpId(j.get("sl").and_then(Json::as_u64).context("sl")?),
        dst_lp: LpId(j.get("dl").and_then(Json::as_u64).context("dl")?),
        payload: P::from_json(j.get("p").context("p")?)?,
    })
}

fn sync_to_json(m: &SyncMsg) -> Json {
    match m {
        SyncMsg::LvtRequest { need, lvt } => Json::obj(vec![
            ("k", Json::str("req")),
            ("need", time_to_json(*need)),
            ("lvt", time_to_json(*lvt)),
        ]),
        SyncMsg::LvtAnnounce { bound } => Json::obj(vec![
            ("k", Json::str("ann")),
            ("bound", time_to_json(*bound)),
        ]),
    }
}

fn sync_from_json(j: &Json) -> Result<SyncMsg> {
    match j.get("k").and_then(Json::as_str) {
        Some("req") => Ok(SyncMsg::LvtRequest {
            need: time_from_json(j.get("need").context("need")?)?,
            lvt: time_from_json(j.get("lvt").context("lvt")?)?,
        }),
        Some("ann") => Ok(SyncMsg::LvtAnnounce {
            bound: time_from_json(j.get("bound").context("bound")?)?,
        }),
        _ => bail!("bad sync msg {j}"),
    }
}

fn control_to_json(c: &ControlMsg) -> Json {
    use ControlMsg::*;
    match c {
        DeployLp {
            context,
            lp,
            kind,
            params,
        } => Json::obj(vec![
            ("k", Json::str("deploy")),
            ("ctx", Json::num(context.raw() as f64)),
            ("lp", Json::num(lp.raw() as f64)),
            ("kind", Json::str(kind.clone())),
            ("params", params.clone()),
        ]),
        RoutingTable { context, routes } => Json::obj(vec![
            ("k", Json::str("routes")),
            ("ctx", Json::num(context.raw() as f64)),
            (
                "routes",
                Json::arr(routes.iter().map(|(l, a)| {
                    Json::arr([Json::num(l.raw() as f64), Json::num(a.raw() as f64)])
                })),
            ),
        ]),
        Bootstrap {
            context,
            time,
            dst,
            payload,
        } => Json::obj(vec![
            ("k", Json::str("bootstrap")),
            ("ctx", Json::num(context.raw() as f64)),
            ("t", time_to_json(*time)),
            ("dst", Json::num(dst.raw() as f64)),
            ("p", payload.clone()),
        ]),
        StartRun {
            context,
            participants,
        } => Json::obj(vec![
            ("k", Json::str("start")),
            ("ctx", Json::num(context.raw() as f64)),
            (
                "parts",
                Json::arr(participants.iter().map(|a| Json::num(a.raw() as f64))),
            ),
        ]),
        Probe { context, round } => Json::obj(vec![
            ("k", Json::str("probe")),
            ("ctx", Json::num(context.raw() as f64)),
            ("round", Json::num(*round as f64)),
        ]),
        ProbeReply {
            context,
            round,
            from,
            idle,
            sent,
            received,
            lvt,
            next_event,
            windows,
        } => Json::obj(vec![
            ("k", Json::str("probe-reply")),
            ("ctx", Json::num(context.raw() as f64)),
            ("round", Json::num(*round as f64)),
            ("from", Json::num(from.raw() as f64)),
            ("idle", Json::Bool(*idle)),
            ("sent", Json::num(*sent as f64)),
            ("received", Json::num(*received as f64)),
            ("lvt", time_to_json(*lvt)),
            ("next", time_to_json(*next_event)),
            ("win", Json::num(*windows as f64)),
        ]),
        GvtUpdate { context, gvt } => Json::obj(vec![
            ("k", Json::str("gvt")),
            ("ctx", Json::num(context.raw() as f64)),
            ("gvt", time_to_json(*gvt)),
        ]),
        EndRun { context } => Json::obj(vec![
            ("k", Json::str("end")),
            ("ctx", Json::num(context.raw() as f64)),
        ]),
        FinalStats {
            context,
            from,
            stats,
        } => Json::obj(vec![
            ("k", Json::str("stats")),
            ("ctx", Json::num(context.raw() as f64)),
            ("from", Json::num(from.raw() as f64)),
            ("stats", stats.clone()),
        ]),
        Result {
            context,
            kind,
            record,
        } => Json::obj(vec![
            ("k", Json::str("result")),
            ("ctx", Json::num(context.raw() as f64)),
            ("kind", Json::str(kind.clone())),
            ("record", record.clone()),
        ]),
        WindowReport {
            context,
            from,
            windows,
            records,
        } => Json::obj(vec![
            ("k", Json::str("wreport")),
            ("ctx", Json::num(context.raw() as f64)),
            ("from", Json::num(from.raw() as f64)),
            ("win", Json::num(*windows as f64)),
            (
                "recs",
                Json::arr(records.iter().map(|(kind, record)| {
                    Json::arr([Json::str(kind.clone()), record.clone()])
                })),
            ),
        ]),
        PerfSample { from, value, load } => Json::obj(vec![
            ("k", Json::str("perf")),
            ("from", Json::num(from.raw() as f64)),
            ("value", Json::num(*value)),
            ("load", load.clone()),
        ]),
        Shutdown => Json::obj(vec![("k", Json::str("shutdown"))]),
    }
}

fn control_from_json(j: &Json) -> Result<ControlMsg> {
    let ctx = || -> Result<ContextId> {
        Ok(ContextId(j.get("ctx").and_then(Json::as_u64).context("ctx")?))
    };
    match j.get("k").and_then(Json::as_str) {
        Some("deploy") => Ok(ControlMsg::DeployLp {
            context: ctx()?,
            lp: LpId(j.get("lp").and_then(Json::as_u64).context("lp")?),
            kind: j
                .get("kind")
                .and_then(Json::as_str)
                .context("kind")?
                .to_string(),
            params: j.get("params").context("params")?.clone(),
        }),
        Some("routes") => {
            let mut routes = Vec::new();
            for r in j.get("routes").and_then(Json::as_arr).context("routes")? {
                let pair = r.as_arr().context("route pair")?;
                routes.push((
                    LpId(pair[0].as_u64().context("lp")?),
                    AgentId(pair[1].as_u64().context("agent")?),
                ));
            }
            Ok(ControlMsg::RoutingTable {
                context: ctx()?,
                routes,
            })
        }
        Some("bootstrap") => Ok(ControlMsg::Bootstrap {
            context: ctx()?,
            time: time_from_json(j.get("t").context("t")?)?,
            dst: LpId(j.get("dst").and_then(Json::as_u64).context("dst")?),
            payload: j.get("p").context("p")?.clone(),
        }),
        Some("start") => Ok(ControlMsg::StartRun {
            context: ctx()?,
            participants: j
                .get("parts")
                .and_then(Json::as_arr)
                .context("parts")?
                .iter()
                .filter_map(Json::as_u64)
                .map(AgentId)
                .collect(),
        }),
        Some("probe") => Ok(ControlMsg::Probe {
            context: ctx()?,
            round: j.get("round").and_then(Json::as_u64).context("round")?,
        }),
        Some("probe-reply") => Ok(ControlMsg::ProbeReply {
            context: ctx()?,
            round: j.get("round").and_then(Json::as_u64).context("round")?,
            from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
            idle: j.get("idle").and_then(Json::as_bool).context("idle")?,
            sent: j.get("sent").and_then(Json::as_u64).context("sent")?,
            received: j
                .get("received")
                .and_then(Json::as_u64)
                .context("received")?,
            lvt: time_from_json(j.get("lvt").context("lvt")?)?,
            next_event: time_from_json(j.get("next").context("next")?)?,
            // Absent in pre-window frames; default keeps mixed fleets
            // decoding.
            windows: j.get("win").and_then(Json::as_u64).unwrap_or(0),
        }),
        Some("gvt") => Ok(ControlMsg::GvtUpdate {
            context: ctx()?,
            gvt: time_from_json(j.get("gvt").context("gvt")?)?,
        }),
        Some("end") => Ok(ControlMsg::EndRun { context: ctx()? }),
        Some("stats") => Ok(ControlMsg::FinalStats {
            context: ctx()?,
            from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
            stats: j.get("stats").context("stats")?.clone(),
        }),
        Some("result") => Ok(ControlMsg::Result {
            context: ctx()?,
            kind: j
                .get("kind")
                .and_then(Json::as_str)
                .context("kind")?
                .to_string(),
            record: j.get("record").context("record")?.clone(),
        }),
        Some("wreport") => {
            let mut records = Vec::new();
            for r in j.get("recs").and_then(Json::as_arr).context("recs")? {
                let pair = r.as_arr().context("record pair")?;
                if pair.len() != 2 {
                    bail!("bad record pair {r}");
                }
                records.push((
                    pair[0].as_str().context("record kind")?.to_string(),
                    pair[1].clone(),
                ));
            }
            Ok(ControlMsg::WindowReport {
                context: ctx()?,
                from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
                windows: j.get("win").and_then(Json::as_u64).context("win")?,
                records,
            })
        }
        Some("perf") => Ok(ControlMsg::PerfSample {
            from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
            value: j.get("value").and_then(Json::as_f64).context("value")?,
            load: j.get("load").context("load")?.clone(),
        }),
        Some("shutdown") => Ok(ControlMsg::Shutdown),
        _ => bail!("bad control msg {j}"),
    }
}

/// Full NetMsg encoding.
pub fn msg_to_json<P: Wire>(m: &NetMsg<P>) -> Json {
    match m {
        NetMsg::Event {
            context,
            event,
            bound,
        } => Json::obj(vec![
            ("k", Json::str("event")),
            ("ctx", Json::num(context.raw() as f64)),
            ("ev", event_to_json(event)),
            ("b", time_to_json(*bound)),
        ]),
        NetMsg::WindowBatch {
            context,
            from,
            events,
            sync,
            bound,
        } => {
            let mut fields = vec![
                ("k", Json::str("batch")),
                ("ctx", Json::num(context.raw() as f64)),
                ("from", Json::num(from.raw() as f64)),
                ("evs", Json::arr(events.iter().map(event_to_json))),
                ("sync", Json::arr(sync.iter().map(sync_to_json))),
            ];
            // Absent key = no promise (non-final split chunk).
            if let Some(b) = bound {
                fields.push(("b", time_to_json(*b)));
            }
            Json::obj(fields)
        }
        NetMsg::Sync { context, from, msg } => Json::obj(vec![
            ("k", Json::str("sync")),
            ("ctx", Json::num(context.raw() as f64)),
            ("from", Json::num(from.raw() as f64)),
            ("msg", sync_to_json(msg)),
        ]),
        NetMsg::Space(op) => Json::obj(vec![("k", Json::str("space")), ("op", op.to_json())]),
        NetMsg::Control(c) => {
            Json::obj(vec![("k", Json::str("control")), ("c", control_to_json(c))])
        }
    }
}

pub fn msg_from_json<P: Wire>(j: &Json) -> Result<NetMsg<P>> {
    match j.get("k").and_then(Json::as_str) {
        Some("event") => Ok(NetMsg::Event {
            context: ContextId(j.get("ctx").and_then(Json::as_u64).context("ctx")?),
            event: event_from_json(j.get("ev").context("ev")?)?,
            bound: time_from_json(j.get("b").context("b")?)?,
        }),
        Some("batch") => {
            let mut events = Vec::new();
            for e in j.get("evs").and_then(Json::as_arr).context("evs")? {
                events.push(event_from_json(e)?);
            }
            let mut sync = Vec::new();
            for s in j.get("sync").and_then(Json::as_arr).context("sync")? {
                sync.push(sync_from_json(s)?);
            }
            Ok(NetMsg::WindowBatch {
                context: ContextId(j.get("ctx").and_then(Json::as_u64).context("ctx")?),
                from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
                events,
                sync,
                bound: match j.get("b") {
                    Some(b) => Some(time_from_json(b)?),
                    None => None,
                },
            })
        }
        Some("sync") => Ok(NetMsg::Sync {
            context: ContextId(j.get("ctx").and_then(Json::as_u64).context("ctx")?),
            from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
            msg: sync_from_json(j.get("msg").context("msg")?)?,
        }),
        Some("space") => Ok(NetMsg::Space(crate::space::SpaceMsg::from_json(
            j.get("op").context("op")?,
        )?)),
        Some("control") => Ok(NetMsg::Control(control_from_json(
            j.get("c").context("c")?,
        )?)),
        _ => bail!("bad net msg {j}"),
    }
}

// ---------------------------------------------------------------------------
// TCP transport
// ---------------------------------------------------------------------------

/// Default ceiling on a single length-prefixed frame, in bytes.  Window
/// batching concentrates a whole window's traffic into one frame, so the
/// default is generous; the limit exists so a corrupt length prefix can
/// never make a reader allocate gigabytes.  Configurable per endpoint via
/// [`TcpTransport::bind_with`] / `dsim agent --max-frame-mib` (the
/// `deploy.max_frame_mib` config knob records the fleet-wide value, which
/// must match on every agent); outbound `WindowBatch` frames above the
/// limit are split, inbound oversized frames are drained and skipped.
pub const DEFAULT_MAX_FRAME_BYTES: usize = 64 << 20;

/// Length-prefixed frame I/O.
fn write_frame(stream: &mut TcpStream, bytes: &[u8]) -> Result<()> {
    let len = (bytes.len() as u32).to_be_bytes();
    stream.write_all(&len)?;
    stream.write_all(bytes)?;
    stream.flush()?;
    Ok(())
}

/// Read one frame, enforcing `max_bytes`.  An oversized frame is *skipped*,
/// not fatal: its body is drained from the stream (keeping frame alignment)
/// and `Ok(None)` is returned, so one bad frame costs its own payload but
/// never poisons the reader thread or the connection behind it.
///
/// A skipped frame can only occur with mismatched per-agent limits (the
/// sender splits against its *own* limit) or a corrupt peer.  Dropped
/// event frames are not silent corruption: the double-count termination
/// protocol sees sent != received forever and the run fails loudly at
/// `max_wall` instead of terminating with wrong results.
fn read_frame(stream: &mut TcpStream, max_bytes: usize) -> Result<Option<Vec<u8>>> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len)?;
    let n = u32::from_be_bytes(len) as usize;
    if n > max_bytes {
        log::error!(
            "skipping oversized frame: {n} bytes > {max_bytes} limit \
             (mismatched --max-frame-mib across the fleet? dropped events \
             will stall termination)"
        );
        let mut chunk = [0u8; 8192];
        let mut remaining = n;
        while remaining > 0 {
            let take = remaining.min(chunk.len());
            stream.read_exact(&mut chunk[..take])?;
            remaining -= take;
        }
        return Ok(None);
    }
    let mut buf = vec![0u8; n];
    stream.read_exact(&mut buf)?;
    Ok(Some(buf))
}

/// TCP endpoint: one listener for inbound peers, one persistent outbound
/// socket per peer (established lazily); reader threads funnel frames into
/// a single inbox channel.
pub struct TcpTransport<P> {
    me: AgentId,
    peers: HashMap<AgentId, SocketAddr>,
    max_frame: usize,
    outbound: Mutex<HashMap<AgentId, TcpStream>>,
    inbox: Mutex<Receiver<NetMsg<P>>>,
    inbox_tx: Sender<NetMsg<P>>,
    _listener: std::thread::JoinHandle<()>,
}

impl<P: Wire + Send + 'static> TcpTransport<P> {
    /// Bind `bind_addr` for `me` and remember the full peer address map
    /// (including self).  Uses the default frame-size limit.
    pub fn bind(
        me: AgentId,
        bind_addr: SocketAddr,
        peers: HashMap<AgentId, SocketAddr>,
    ) -> Result<Self> {
        Self::bind_with(me, bind_addr, peers, DEFAULT_MAX_FRAME_BYTES)
    }

    /// [`bind`](Self::bind) with an explicit frame-size limit in bytes.
    pub fn bind_with(
        me: AgentId,
        bind_addr: SocketAddr,
        peers: HashMap<AgentId, SocketAddr>,
        max_frame: usize,
    ) -> Result<Self> {
        let listener =
            TcpListener::bind(bind_addr).with_context(|| format!("bind {bind_addr} for {me}"))?;
        Self::from_listener(me, listener, peers, max_frame)
    }

    /// Build an endpoint from an already-bound listener.  Lets callers use
    /// OS-assigned ports: bind `127.0.0.1:0` listeners first, collect their
    /// `local_addr()`s into the peer map, then construct every endpoint —
    /// the pattern the cross-transport test suite uses to avoid port
    /// collisions.
    pub fn from_listener(
        me: AgentId,
        listener: TcpListener,
        peers: HashMap<AgentId, SocketAddr>,
        max_frame: usize,
    ) -> Result<Self> {
        let (tx, rx) = channel();
        let tx_accept = tx.clone();
        let handle = std::thread::Builder::new()
            .name(format!("dsim-tcp-accept-{me}"))
            .spawn(move || {
                for stream in listener.incoming() {
                    let Ok(mut stream) = stream else { break };
                    let tx = tx_accept.clone();
                    std::thread::spawn(move || loop {
                        match read_frame(&mut stream, max_frame) {
                            // Oversized frame skipped; connection still good.
                            Ok(None) => continue,
                            Ok(Some(bytes)) => {
                                let Ok(text) = std::str::from_utf8(&bytes) else { break };
                                match Json::parse(text)
                                    .map_err(anyhow::Error::from)
                                    .and_then(|j| msg_from_json::<P>(&j))
                                {
                                    Ok(msg) => {
                                        if tx.send(msg).is_err() {
                                            break;
                                        }
                                    }
                                    Err(e) => {
                                        log::error!("bad frame: {e}");
                                        break;
                                    }
                                }
                            }
                            Err(_) => break,
                        }
                    });
                }
            })?;
        Ok(TcpTransport {
            me,
            peers,
            max_frame,
            outbound: Mutex::new(HashMap::new()),
            inbox: Mutex::new(rx),
            inbox_tx: tx,
            _listener: handle,
        })
    }

    fn connect(&self, to: AgentId) -> Result<TcpStream> {
        let addr = self
            .peers
            .get(&to)
            .ok_or_else(|| anyhow!("unknown peer {to}"))?;
        // Retry briefly: peers race to bind at startup.
        let mut last = None;
        for _ in 0..50 {
            match TcpStream::connect(addr) {
                Ok(s) => {
                    s.set_nodelay(true).ok();
                    return Ok(s);
                }
                Err(e) => {
                    last = Some(e);
                    std::thread::sleep(Duration::from_millis(100));
                }
            }
        }
        Err(anyhow!("connect {to} at {addr}: {last:?}"))
    }

    /// Encode and transmit one frame, splitting over-limit batch frames
    /// into smaller chunks: a [`NetMsg::WindowBatch`] by halving its event
    /// list (non-final chunks carry no sync flush and no bound, so the
    /// promise stays behind every event it covers), a
    /// [`ControlMsg::WindowReport`] by halving its record list (the
    /// cumulative window count is idempotent).  Anything else over the
    /// limit is a hard error — the receiver would drain and drop it
    /// anyway.
    fn send_framed(&self, to: AgentId, msg: NetMsg<P>) -> Result<()> {
        let text = msg_to_json(&msg).to_string();
        if text.len() > self.max_frame {
            match msg {
                NetMsg::WindowBatch {
                    context,
                    from,
                    mut events,
                    sync,
                    bound,
                } if events.len() > 1 => {
                    let tail = events.split_off(events.len() / 2);
                    self.send_framed(
                        to,
                        NetMsg::WindowBatch {
                            context,
                            from,
                            events,
                            sync: Vec::new(),
                            bound: None,
                        },
                    )?;
                    return self.send_framed(
                        to,
                        NetMsg::WindowBatch {
                            context,
                            from,
                            events: tail,
                            sync,
                            bound,
                        },
                    );
                }
                NetMsg::Control(ControlMsg::WindowReport {
                    context,
                    from,
                    windows,
                    mut records,
                }) if records.len() > 1 => {
                    let tail = records.split_off(records.len() / 2);
                    self.send_framed(
                        to,
                        NetMsg::Control(ControlMsg::WindowReport {
                            context,
                            from,
                            windows,
                            records,
                        }),
                    )?;
                    return self.send_framed(
                        to,
                        NetMsg::Control(ControlMsg::WindowReport {
                            context,
                            from,
                            windows,
                            records: tail,
                        }),
                    );
                }
                _ => bail!(
                    "frame too large: {} bytes > {} limit (unsplittable)",
                    text.len(),
                    self.max_frame
                ),
            }
        }
        let mut outbound = self.outbound.lock().unwrap();
        if !outbound.contains_key(&to) {
            let s = self.connect(to)?;
            outbound.insert(to, s);
        }
        let stream = outbound.get_mut(&to).unwrap();
        if let Err(e) = write_frame(stream, text.as_bytes()) {
            // One reconnect attempt on a stale socket.
            log::warn!("resend to {to} after {e}");
            let mut s = self.connect(to)?;
            write_frame(&mut s, text.as_bytes())?;
            outbound.insert(to, s);
        }
        Ok(())
    }
}

impl<P: Wire + Clone + Send + 'static> Transport<P> for TcpTransport<P> {
    fn me(&self) -> AgentId {
        self.me
    }

    fn agents(&self) -> Vec<AgentId> {
        let mut v: Vec<AgentId> = self.peers.keys().copied().collect();
        v.sort();
        v
    }

    fn send(&self, to: AgentId, msg: NetMsg<P>) -> Result<()> {
        if to == self.me {
            // Loopback without a socket.
            self.inbox_tx
                .send(msg)
                .map_err(|_| anyhow!("self inbox closed"))?;
            return Ok(());
        }
        self.send_framed(to, msg)
    }

    fn recv_timeout(&self, timeout: Duration) -> Option<NetMsg<P>> {
        let rx = self.inbox.lock().unwrap();
        if timeout.is_zero() {
            rx.try_recv().ok()
        } else {
            rx.recv_timeout(timeout).ok()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inproc_roundtrip_and_order() {
        let net: InProcNetwork<u32> = InProcNetwork::new();
        let a = net.endpoint(AgentId(1));
        let b = net.endpoint(AgentId(2));
        for i in 0..10u64 {
            a.send(
                AgentId(2),
                NetMsg::Control(ControlMsg::Probe {
                    context: ContextId(i),
                    round: 0,
                }),
            )
            .unwrap();
        }
        for i in 0..10u64 {
            match b.recv_timeout(Duration::from_secs(1)).unwrap() {
                NetMsg::Control(ControlMsg::Probe { context, .. }) => {
                    assert_eq!(context, ContextId(i)); // FIFO preserved
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(net.total_sent(), 10);
    }

    #[test]
    fn inproc_unknown_agent_errors() {
        let net: InProcNetwork<u32> = InProcNetwork::new();
        let a = net.endpoint(AgentId(1));
        assert!(a
            .send(AgentId(9), NetMsg::Control(ControlMsg::Shutdown))
            .is_err());
    }

    #[test]
    fn wire_event_roundtrip() {
        let ev = Event {
            time: SimTime::new(1.5),
            tie: (3, 42),
            src_agent: AgentId(3),
            src_lp: LpId(7),
            dst_lp: LpId(8),
            payload: 99u32,
        };
        let j = event_to_json(&ev);
        let back: Event<u32> = event_from_json(&j).unwrap();
        assert_eq!(back.time, ev.time);
        assert_eq!(back.tie, ev.tie);
        assert_eq!(back.payload, 99);
    }

    #[test]
    fn wire_sync_roundtrip_with_infinities() {
        for m in [
            SyncMsg::LvtRequest {
                need: SimTime::new(2.0),
                lvt: SimTime::NEG_INF,
            },
            SyncMsg::LvtAnnounce { bound: SimTime::INF },
        ] {
            let j = sync_to_json(&m);
            assert_eq!(sync_from_json(&j).unwrap(), m);
        }
    }

    #[test]
    fn wire_control_roundtrip() {
        let msgs = vec![
            ControlMsg::DeployLp {
                context: ContextId(1),
                lp: LpId(5),
                kind: "cpu".into(),
                params: Json::obj(vec![("power", Json::num(2.5))]),
            },
            ControlMsg::RoutingTable {
                context: ContextId(1),
                routes: vec![(LpId(1), AgentId(2)), (LpId(3), AgentId(4))],
            },
            ControlMsg::ProbeReply {
                context: ContextId(2),
                round: 7,
                from: AgentId(1),
                idle: true,
                sent: 10,
                received: 10,
                lvt: SimTime::new(3.5),
                next_event: SimTime::INF,
                windows: 42,
            },
            ControlMsg::GvtUpdate {
                context: ContextId(1),
                gvt: SimTime::new(4.5),
            },
            ControlMsg::WindowReport {
                context: ContextId(3),
                from: AgentId(2),
                windows: 9,
                records: vec![
                    ("job".into(), Json::num(1.0)),
                    ("transfer".into(), Json::obj(vec![("mb", Json::num(2.0))])),
                ],
            },
            ControlMsg::WindowReport {
                context: ContextId(3),
                from: AgentId(2),
                windows: 10,
                records: vec![], // progress-only notification
            },
            ControlMsg::Shutdown,
        ];
        for m in msgs {
            let j = control_to_json(&m);
            assert_eq!(control_from_json(&j).unwrap(), m);
        }
    }

    // ------------------------------------------------------------------
    // Property-style codec coverage (satellite: every NetMsg variant,
    // including WindowBatch and the legacy pre-batch frames, through the
    // full encode -> serialize -> parse -> decode -> re-encode cycle).
    // ------------------------------------------------------------------

    use crate::util::Pcg32;

    fn rand_time(rng: &mut Pcg32) -> SimTime {
        match rng.below(10) {
            0 => SimTime::INF,
            1 => SimTime::NEG_INF,
            _ => SimTime::new(rng.uniform(0.0, 1e6)),
        }
    }

    fn rand_event(rng: &mut Pcg32) -> Event<u32> {
        Event {
            time: SimTime::new(rng.uniform(0.0, 1e6)),
            tie: (rng.below(8), rng.next_u32() as u64),
            src_agent: AgentId(rng.below(8)),
            src_lp: LpId(rng.below(64)),
            dst_lp: LpId(rng.below(64)),
            payload: rng.next_u32(),
        }
    }

    fn rand_sync(rng: &mut Pcg32) -> SyncMsg {
        if rng.chance(0.5) {
            SyncMsg::LvtRequest {
                need: rand_time(rng),
                lvt: rand_time(rng),
            }
        } else {
            SyncMsg::LvtAnnounce { bound: rand_time(rng) }
        }
    }

    fn rand_json(rng: &mut Pcg32) -> Json {
        Json::obj(vec![
            ("x", Json::num(rng.uniform(-10.0, 10.0))),
            ("s", Json::str(format!("v{}", rng.below(100)))),
        ])
    }

    fn rand_control(rng: &mut Pcg32) -> ControlMsg {
        let ctx = ContextId(rng.below(4));
        match rng.below(13) {
            0 => ControlMsg::DeployLp {
                context: ctx,
                lp: LpId(rng.below(64)),
                kind: format!("kind{}", rng.below(4)),
                params: rand_json(rng),
            },
            1 => ControlMsg::RoutingTable {
                context: ctx,
                routes: (0..rng.below(5))
                    .map(|i| (LpId(i), AgentId(rng.below(4))))
                    .collect(),
            },
            2 => ControlMsg::Bootstrap {
                context: ctx,
                time: rand_time(rng),
                dst: LpId(rng.below(64)),
                payload: rand_json(rng),
            },
            3 => ControlMsg::StartRun {
                context: ctx,
                participants: (1..=rng.below(5) + 1).map(AgentId).collect(),
            },
            4 => ControlMsg::Probe {
                context: ctx,
                round: rng.below(100),
            },
            5 => ControlMsg::ProbeReply {
                context: ctx,
                round: rng.below(100),
                from: AgentId(rng.below(8)),
                idle: rng.chance(0.5),
                sent: rng.below(1000),
                received: rng.below(1000),
                lvt: rand_time(rng),
                next_event: rand_time(rng),
                windows: rng.below(1000),
            },
            6 => ControlMsg::GvtUpdate {
                context: ctx,
                gvt: rand_time(rng),
            },
            7 => ControlMsg::EndRun { context: ctx },
            8 => ControlMsg::FinalStats {
                context: ctx,
                from: AgentId(rng.below(8)),
                stats: rand_json(rng),
            },
            9 => ControlMsg::Result {
                context: ctx,
                kind: format!("kind{}", rng.below(4)),
                record: rand_json(rng),
            },
            10 => ControlMsg::WindowReport {
                context: ctx,
                from: AgentId(rng.below(8)),
                windows: rng.below(10_000),
                records: (0..rng.below(4))
                    .map(|_| (format!("k{}", rng.below(3)), rand_json(rng)))
                    .collect(),
            },
            11 => ControlMsg::PerfSample {
                from: AgentId(rng.below(8)),
                value: rng.uniform(0.0, 10.0),
                load: rand_json(rng),
            },
            _ => ControlMsg::Shutdown,
        }
    }

    fn rand_msg(rng: &mut Pcg32) -> NetMsg<u32> {
        let ctx = ContextId(rng.below(4));
        match rng.below(5) {
            0 => NetMsg::Event {
                context: ctx,
                event: rand_event(rng),
                bound: rand_time(rng),
            },
            1 => NetMsg::WindowBatch {
                context: ctx,
                from: AgentId(rng.below(8)),
                events: (0..rng.below(6)).map(|_| rand_event(rng)).collect(),
                sync: (0..rng.below(4)).map(|_| rand_sync(rng)).collect(),
                bound: if rng.chance(0.7) {
                    Some(rand_time(rng))
                } else {
                    None // non-final split chunk
                },
            },
            2 => NetMsg::Sync {
                context: ctx,
                from: AgentId(rng.below(8)),
                msg: rand_sync(rng),
            },
            3 => NetMsg::Space(crate::space::SpaceMsg::Remove {
                key: format!("key{}", rng.below(10)),
                version: rng.below(100),
            }),
            _ => NetMsg::Control(rand_control(rng)),
        }
    }

    #[test]
    fn wire_roundtrip_property_every_variant() {
        crate::testkit::check("netmsg wire roundtrip", 300, |rng| {
            let msg = rand_msg(rng);
            // The full wire cycle: encode, serialize, parse, decode,
            // re-encode.  Byte-identical re-encoding implies the decode
            // lost nothing (serialization is deterministic).
            let text = msg_to_json(&msg).to_string();
            let parsed = Json::parse(&text).map_err(|e| format!("parse: {e}"))?;
            let back: NetMsg<u32> =
                msg_from_json(&parsed).map_err(|e| format!("decode {text}: {e:#}"))?;
            let text2 = msg_to_json(&back).to_string();
            if text == text2 {
                Ok(())
            } else {
                Err(format!("re-encode mismatch:\n  {text}\n  {text2}"))
            }
        });
    }

    #[test]
    fn legacy_pre_batch_frames_still_decode() {
        // Exact pre-batch wire frames (one frame per message): the new
        // codec must accept them verbatim so mixed fleets interoperate.
        let event = r#"{"k":"event","ctx":1,"ev":{"t":9,"tie0":1,"tie1":1,"sa":1,"sl":1,"dl":2,"p":7},"b":9}"#;
        match msg_from_json::<u32>(&Json::parse(event).unwrap()).unwrap() {
            NetMsg::Event { event, bound, .. } => {
                assert_eq!(event.payload, 7);
                assert_eq!(bound, SimTime::new(9.0));
            }
            other => panic!("unexpected {other:?}"),
        }
        let sync = r#"{"k":"sync","ctx":1,"from":2,"msg":{"k":"ann","bound":"inf"}}"#;
        match msg_from_json::<u32>(&Json::parse(sync).unwrap()).unwrap() {
            NetMsg::Sync {
                msg: SyncMsg::LvtAnnounce { bound },
                from,
                ..
            } => {
                assert_eq!(bound, SimTime::INF);
                assert_eq!(from, AgentId(2));
            }
            other => panic!("unexpected {other:?}"),
        }
        // Pre-window ProbeReply without the "win" field defaults to 0.
        let reply = r#"{"k":"control","c":{"k":"probe-reply","ctx":1,"round":3,"from":2,"idle":true,"sent":4,"received":4,"lvt":1.5,"next":"inf"}}"#;
        match msg_from_json::<u32>(&Json::parse(reply).unwrap()).unwrap() {
            NetMsg::Control(ControlMsg::ProbeReply { windows, round, .. }) => {
                assert_eq!(windows, 0);
                assert_eq!(round, 3);
            }
            other => panic!("unexpected {other:?}"),
        }
        // A batch frame without "b" (non-final split chunk): bound = None.
        let chunk = r#"{"k":"batch","ctx":1,"from":2,"evs":[],"sync":[]}"#;
        match msg_from_json::<u32>(&Json::parse(chunk).unwrap()).unwrap() {
            NetMsg::WindowBatch { bound, events, .. } => {
                assert!(bound.is_none());
                assert!(events.is_empty());
            }
            other => panic!("unexpected {other:?}"),
        }
        // Garbage frames are rejected, not panicked on.
        assert!(msg_from_json::<u32>(&Json::parse(r#"{"k":"bogus"}"#).unwrap()).is_err());
    }

    // ------------------------------------------------------------------
    // Frame-size limit: oversized frames fail cleanly on both sides.
    // ------------------------------------------------------------------

    #[test]
    fn read_frame_skips_oversized_and_recovers() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (mut server, _) = listener.accept().unwrap();
        write_frame(&mut client, &[b'x'; 100]).unwrap();
        write_frame(&mut client, b"ok").unwrap();
        // The 100-byte frame exceeds the limit: skipped (drained), and the
        // next frame on the same stream still reads correctly.
        assert!(read_frame(&mut server, 16).unwrap().is_none());
        assert_eq!(read_frame(&mut server, 16).unwrap().unwrap(), b"ok");
    }

    #[test]
    fn oversized_inbound_frame_does_not_poison_reader() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peers: HashMap<AgentId, SocketAddr> = [(AgentId(1), addr)].into_iter().collect();
        let t: TcpTransport<u32> =
            TcpTransport::from_listener(AgentId(1), listener, peers, 1024).unwrap();
        // A rogue peer writes an oversized frame, then a valid one, on the
        // same connection: the reader thread must survive and deliver the
        // valid message.
        let mut rogue = TcpStream::connect(addr).unwrap();
        write_frame(&mut rogue, &[b'x'; 4096]).unwrap();
        let valid: NetMsg<u32> = NetMsg::Control(ControlMsg::Shutdown);
        write_frame(&mut rogue, msg_to_json(&valid).to_string().as_bytes()).unwrap();
        assert!(matches!(
            t.recv_timeout(Duration::from_secs(5)).unwrap(),
            NetMsg::Control(ControlMsg::Shutdown)
        ));
    }

    #[test]
    fn oversized_window_batch_splits_and_reassembles() {
        // Two endpoints with a tiny frame limit: a large batch must arrive
        // complete, in order, as several chunks, with the sync flush and
        // the promise riding only the final chunk.
        let (l1, l2) = (
            TcpListener::bind("127.0.0.1:0").unwrap(),
            TcpListener::bind("127.0.0.1:0").unwrap(),
        );
        let peers: HashMap<AgentId, SocketAddr> = [
            (AgentId(1), l1.local_addr().unwrap()),
            (AgentId(2), l2.local_addr().unwrap()),
        ]
        .into_iter()
        .collect();
        let t1: TcpTransport<u32> =
            TcpTransport::from_listener(AgentId(1), l1, peers.clone(), 256).unwrap();
        let t2: TcpTransport<u32> =
            TcpTransport::from_listener(AgentId(2), l2, peers, 256).unwrap();
        let events: Vec<Event<u32>> = (0..8u64)
            .map(|i| Event {
                time: SimTime::new(i as f64),
                tie: (1, i),
                src_agent: AgentId(1),
                src_lp: LpId(1),
                dst_lp: LpId(2),
                payload: i as u32,
            })
            .collect();
        t1.send(
            AgentId(2),
            NetMsg::WindowBatch {
                context: ContextId(1),
                from: AgentId(1),
                events,
                sync: vec![SyncMsg::LvtAnnounce { bound: SimTime::new(99.0) }],
                bound: Some(SimTime::new(99.0)),
            },
        )
        .unwrap();
        let mut got = Vec::new();
        let mut bounds = Vec::new();
        let mut syncs = 0;
        while got.len() < 8 {
            match t2.recv_timeout(Duration::from_secs(5)).expect("batch chunk") {
                NetMsg::WindowBatch { events, sync, bound, .. } => {
                    got.extend(events.into_iter().map(|e| e.payload));
                    syncs += sync.len();
                    bounds.push(bound);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(got, (0..8u32).collect::<Vec<_>>());
        assert!(bounds.len() > 1, "batch should have split");
        assert!(bounds.last().unwrap().is_some(), "final chunk carries the bound");
        assert!(bounds[..bounds.len() - 1].iter().all(Option::is_none));
        assert_eq!(syncs, 1, "sync flush rides the final chunk only");
    }

    #[test]
    fn unsplittable_oversized_frame_errors_on_send() {
        let (l1, l2) = (
            TcpListener::bind("127.0.0.1:0").unwrap(),
            TcpListener::bind("127.0.0.1:0").unwrap(),
        );
        let peers: HashMap<AgentId, SocketAddr> = [
            (AgentId(1), l1.local_addr().unwrap()),
            (AgentId(2), l2.local_addr().unwrap()),
        ]
        .into_iter()
        .collect();
        let t1: TcpTransport<u32> =
            TcpTransport::from_listener(AgentId(1), l1, peers.clone(), 64).unwrap();
        let _t2: TcpTransport<u32> =
            TcpTransport::from_listener(AgentId(2), l2, peers, 64).unwrap();
        // A control frame cannot be split; over the limit it must error
        // rather than ship a frame the receiver would drain and drop.
        let big = ControlMsg::Result {
            context: ContextId(1),
            kind: "x".repeat(128),
            record: Json::Null,
        };
        assert!(t1.send(AgentId(2), NetMsg::Control(big)).is_err());
    }

    #[test]
    fn tcp_roundtrip_two_endpoints() {
        let addr1: SocketAddr = "127.0.0.1:39121".parse().unwrap();
        let addr2: SocketAddr = "127.0.0.1:39122".parse().unwrap();
        let peers: HashMap<AgentId, SocketAddr> = [(AgentId(1), addr1), (AgentId(2), addr2)]
            .into_iter()
            .collect();
        let t1: TcpTransport<u32> = TcpTransport::bind(AgentId(1), addr1, peers.clone()).unwrap();
        let t2: TcpTransport<u32> = TcpTransport::bind(AgentId(2), addr2, peers).unwrap();

        t1.send(
            AgentId(2),
            NetMsg::Event {
                context: ContextId(1),
                event: Event {
                    time: SimTime::new(9.0),
                    tie: (1, 1),
                    src_agent: AgentId(1),
                    src_lp: LpId(1),
                    dst_lp: LpId(2),
                    payload: 7u32,
                },
                bound: SimTime::new(9.0),
            },
        )
        .unwrap();
        match t2.recv_timeout(Duration::from_secs(5)).unwrap() {
            NetMsg::Event { event, .. } => {
                assert_eq!(event.payload, 7);
                assert_eq!(event.time, SimTime::new(9.0));
            }
            other => panic!("unexpected {other:?}"),
        }

        // Reply direction.
        t2.send(AgentId(1), NetMsg::Control(ControlMsg::Shutdown))
            .unwrap();
        assert!(matches!(
            t1.recv_timeout(Duration::from_secs(5)).unwrap(),
            NetMsg::Control(ControlMsg::Shutdown)
        ));
    }
}
