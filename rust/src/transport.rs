//! Agent-to-agent message transport.
//!
//! The framework runs in two deployment modes:
//!
//! * **In-process** ([`InProcNetwork`]) — every agent is a thread in one OS
//!   process; messages travel over `std::sync::mpsc` channels.  This is the
//!   default for tests, benches and single-machine studies.
//! * **TCP** ([`TcpTransport`]) — agents are separate OS processes
//!   (possibly on different hosts); messages are length-prefixed JSON
//!   frames over persistent sockets.  Payloads must implement [`Wire`].
//!
//! Both implement [`Transport`], so the engine/agent layers are agnostic.
//! Channels are FIFO per (src, dst) pair — the property the conservative
//! protocol relies on (a channel's head timestamp bounds the channel).
//!
//! ## Window-batched frame schema
//!
//! Safe-window execution flushes an engine's outbox once per window, so the
//! wire protocol batches at the same granularity: a flush produces **one
//! [`NetMsg::WindowBatch`] frame per destination peer**, carrying every
//! event of the window bound for that peer (in emission order), the
//! window's sync messages for that peer, and a single piggybacked promise
//! (`bound`) applied *after* the frame's events — plus at most **one
//! [`ControlMsg::WindowReport`] frame to the leader** carrying the window's
//! published result records and the sender's cumulative executed-window
//! count (the leader's GVT progress signal).  Frames per window are
//! therefore O(peers), not O(messages).
//!
//! The atomic frame is what makes the single trailing `bound` sound: the
//! receiver ingests the frame's events before observing the promise, and
//! every *future* send to that peer is ≥ the post-drain bound by the same
//! argument that justifies [`LvtAnnounce`](crate::engine::SyncMsg)
//! bounds.  A `WindowBatch` whose encoding exceeds the frame-size limit is
//! split transparently; non-final chunks carry no sync flush and no bound,
//! so promise ordering survives the split.
//!
//! The pre-batch frames (`event`, `sync`, one frame per message) remain
//! fully supported: they are still emitted when wire batching is disabled
//! (`deploy.wire_batch = false`) and always decode, so mixed old/new
//! fleets interoperate.
//!
//! ## Wire-format specification (TCP mode)
//!
//! Every frame is `u32 big-endian body length | body`, capped at a
//! configurable limit ([`DEFAULT_MAX_FRAME_BYTES`]); an inbound oversized
//! frame is drained and skipped — one bad frame never poisons its reader
//! thread or connection.  The *body* encoding is the connection's
//! [`WireCodec`], chosen by the sender per connection:
//!
//! * **Connection preamble** — a binary connection opens with the 6-byte
//!   preamble `b"DSIM" | version u8 | codec u8` before its first frame.
//!   A JSON connection sends **no preamble**: its byte stream is exactly
//!   the pre-codec (PR 2) protocol, which is what makes
//!   `--wire-codec json` the mixed-fleet interop fallback.  Receivers
//!   sniff the first four bytes: the magic can never collide with a sane
//!   frame length (it would imply a >1 GiB frame), so preamble-less
//!   streams from both old peers and JSON-codec peers are recognized and
//!   decoded as JSON text.  Caveat for fleets that use the object space:
//!   pre-space receivers ignore the batch frame's `sp` key (unknown JSON
//!   keys don't error), so space replication toward them also needs
//!   `wire_batch = false` (standalone `Space` frames) — `wire_codec =
//!   json` alone only covers the event/sync/control plane.
//! * **[`WireCodec::Json`]** (tag 0) — the body is the compact JSON text
//!   of [`msg_to_json`]; human-readable on the wire, interoperable with
//!   pre-codec fleets, and the debugging format.
//! * **[`WireCodec::Binary`]** (tag 1, default) — the body is the binary
//!   encoding below.  Primitives (see [`crate::util::bin`]): unsigned
//!   integers are ULEB128 varints; `f64` is 8 raw little-endian IEEE-754
//!   bits, so timestamps round-trip **bit-exactly** with no float
//!   printing/parsing on the hot path; strings are varint-length-prefixed
//!   UTF-8; `vec<T>` is a varint count then elements; `opt<T>` is a 0/1
//!   byte then the value; JSON trees use the tagged form of
//!   [`Json::encode_bin`].
//!
//!   ```text
//!   msg    := tag u8 ...
//!     1 Event        ctx, event, bound f64
//!     2 WindowBatch  ctx, from, vec<event>, vec<sync>, vec<space>, opt<f64 bound>
//!     3 Sync         ctx, from, sync
//!     4 Space        space
//!     5 Control      control
//!   event  := time f64, tie0, tie1, src_agent, src_lp, dst_lp, payload
//!   sync   := 1 LvtRequest(need f64, lvt f64) | 2 LvtAnnounce(bound f64)
//!   space  := 1 Write(key str, fields json, version, writer)
//!           | 2 Remove(key str, version)
//!   control:= tag u8 ...   (tags 1..=13, field order matches the struct
//!             declaration; see `control_to_bin`)
//!   ```
//!
//!   Payload encoding is [`Wire::encode_bin`]: the default bridges
//!   through the JSON tree (still raw-bit f64, no text); hot payloads
//!   (the MONARC [`Payload`](crate::model::Payload)) override it with a
//!   dedicated tag+fields form.
//!
//! **Versioning rules.**  New message kinds take fresh tag values; an
//! unknown tag is a decode error that drops only its own connection
//! (fail loud, never silent corruption).  Any change to an *existing*
//! field layout must bump [`WIRE_VERSION`], which rejects the connection
//! at the preamble.  The JSON codec is the long-horizon interop format:
//! mixed or upgrading fleets run `--wire-codec json` until every agent
//! speaks the same binary version.
//!
//! ## Per-peer writer threads
//!
//! [`TcpTransport::send`] never touches a socket: it enqueues the message
//! on a **bounded per-peer writer queue**
//! ([`TcpOptions::writer_queue`]).  A dedicated writer thread per peer
//! encodes frames and performs the blocking `write`, so serialization and
//! socket stalls overlap with window execution on the agent thread.  A
//! full queue **blocks the sender** — backpressure, never loss:
//! conservative sync frames cannot be lossy.  Per-peer FIFO order is
//! preserved (single queue, single writer).  Dropping the transport
//! closes every queue, and each writer drains what is already queued
//! before exiting (joined in `Drop`), so shutdown flushes rather than
//! truncates.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write as IoWrite};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::HostStatsView;
use crate::engine::{Event, SimTime, SyncMsg};
use crate::space::SpaceMsg;
use crate::trace::{PhaseProfile, SpanKind, TraceSpan};
use crate::util::bin;
use crate::util::json::Json;
use crate::util::{AgentId, ContextId, LpId};

// ---------------------------------------------------------------------------
// Messages
// ---------------------------------------------------------------------------

/// Control-plane messages (deployment, termination detection, monitoring).
#[derive(Clone, Debug, PartialEq)]
pub enum ControlMsg {
    /// Leader -> agent: install an LP of `kind` with JSON params.
    DeployLp {
        context: ContextId,
        lp: LpId,
        kind: String,
        params: Json,
    },
    /// Leader -> agent: full LP->agent routing table for a context.
    RoutingTable {
        context: ContextId,
        routes: Vec<(LpId, AgentId)>,
    },
    /// Leader -> agent: inject a bootstrap event.
    Bootstrap {
        context: ContextId,
        time: SimTime,
        dst: LpId,
        payload: Json,
    },
    /// Leader -> agent: begin executing a context.  `participants` is the
    /// set of agents actually hosting LPs of this context — only they take
    /// part in conservative synchronization (a fleet member with no LPs
    /// would otherwise be dead weight the demand protocol keeps polling).
    StartRun {
        context: ContextId,
        participants: Vec<AgentId>,
    },
    /// Termination detection probe (double-count algorithm).
    Probe { context: ContextId, round: u64 },
    /// Agent -> leader: probe answer (idle?, #sent, #received, lvt,
    /// earliest pending event, safe windows executed).
    ProbeReply {
        context: ContextId,
        round: u64,
        from: AgentId,
        idle: bool,
        sent: u64,
        received: u64,
        lvt: SimTime,
        next_event: SimTime,
        /// Total safe windows this agent has executed for the context —
        /// the termination detector's progress signal at window
        /// granularity.
        windows: u64,
    },
    /// Leader -> agents: proven GVT lower bound (quiescent probe round).
    GvtUpdate { context: ContextId, gvt: SimTime },
    /// Leader -> agents: context finished; tear down and report stats.
    EndRun { context: ContextId },
    /// Agent -> leader: final per-agent statistics.  Typed end-to-end —
    /// in-process deployments move the struct directly with zero JSON
    /// construction; the wire codecs serialize it through the same JSON
    /// tree as before (see [`HostStatsView::to_json`]), so the frame
    /// layout is unchanged and old fleets still decode.
    FinalStats {
        context: ContextId,
        from: AgentId,
        stats: HostStatsView,
    },
    /// Agent -> leader: published simulation result record (pre-batch
    /// frame; still accepted, and emitted when wire batching is off).
    Result {
        context: ContextId,
        kind: String,
        record: Json,
    },
    /// Agent -> leader, once per flushed window: every result record the
    /// window published, plus the sender's cumulative executed-window
    /// count.  Replaces one `Result` frame per record with one frame per
    /// window, and doubles as the window-completion notification that
    /// triggers leader GVT probe rounds on virtual progress.
    WindowReport {
        context: ContextId,
        from: AgentId,
        /// Total safe windows the sender has executed for the context.
        windows: u64,
        records: Vec<(String, Json)>,
    },
    /// Monitoring: an agent's published performance sample.
    PerfSample { from: AgentId, value: f64, load: Json },
    /// Graceful process shutdown (TCP mode).
    Shutdown,
    /// Agent -> leader: periodic liveness beacon (multi-process fleets).
    /// `seq` increments monotonically per agent, so the leader can tell a
    /// stalled sender from a slow control channel.
    Heartbeat { from: AgentId, seq: u64 },
    /// Agent -> leader: the agent hit a fatal local error (writer death,
    /// poisoned connection) and is exiting.  Carries the reason so the
    /// leader's abort report names the first failure, not a symptom.
    AgentFailed { from: AgentId, reason: String },
    /// Leader -> agents: begin checkpoint barrier `ckpt` for `context`.
    /// The agent pauses stepping at its current window boundary, flushes
    /// its outbox, and answers with [`ControlMsg::CheckpointReply`].
    CheckpointStart { context: ContextId, ckpt: u64 },
    /// Agent -> leader: paused for checkpoint `ckpt`, with the agent's
    /// cumulative event-message counters.  The leader declares the fleet
    /// quiescent when sum(sent) == sum(received) across one poll round's
    /// replies — no event frame still in flight anywhere.
    CheckpointReply {
        context: ContextId,
        ckpt: u64,
        from: AgentId,
        sent: u64,
        received: u64,
    },
    /// Leader -> agents: re-request [`ControlMsg::CheckpointReply`] while
    /// the barrier waits for in-flight frames to drain.
    CheckpointPoll { context: ContextId, ckpt: u64 },
    /// Leader -> agents: the fleet is quiescent at the barrier; write
    /// checkpoint `ckpt` to disk, answer [`ControlMsg::CheckpointDone`],
    /// and resume stepping.
    CheckpointCommit { context: ContextId, ckpt: u64 },
    /// Agent -> leader: checkpoint `ckpt` written (`err` empty) or failed
    /// (`err` names the cause).
    CheckpointDone {
        context: ContextId,
        ckpt: u64,
        from: AgentId,
        err: String,
    },
    /// Leader -> agents: load checkpoint `ckpt` from disk and restore the
    /// context's engine to it (recovery after an agent failure).
    Rollback { context: ContextId, ckpt: u64 },
    /// Agent -> leader: rollback to `ckpt` finished (`err` empty) or
    /// failed (`err` names the cause).
    RollbackDone {
        context: ContextId,
        ckpt: u64,
        from: AgentId,
        err: String,
    },
    /// Agent -> leader: periodic live-telemetry snapshot.  Emitted every
    /// `telemetry_windows` *executed windows* — a virtual-time cadence,
    /// never a wall-clock timer — so enabling telemetry cannot perturb
    /// the determinism fingerprint.  Pure monitoring: leaders fold these
    /// into per-agent time-series; drive loops that predate the frame
    /// ignore it via their catch-all arms.
    Telemetry {
        context: ContextId,
        from: AgentId,
        snap: TelemetrySnapshot,
    },
    /// Agent -> leader: one chunk of the agent's virtual-time trace (see
    /// [`crate::trace`]), emitted at EndRun *before* [`ControlMsg::FinalStats`]
    /// — the per-agent control channel is FIFO, so the leader holds the
    /// complete trace by the time stats arrive.  `seq` numbers the chunks;
    /// `dropped` is the ring-cap drop count (repeated on every chunk).
    /// Pure observability: never folded into fingerprints; drive loops
    /// that predate the frame ignore it via their catch-all arms.
    TraceChunk {
        context: ContextId,
        from: AgentId,
        seq: u64,
        dropped: u64,
        spans: Vec<TraceSpan>,
    },
    /// Agent -> leader: the run's wall-clock phase profile (see
    /// [`crate::trace::PhaseProfile`]), emitted once at EndRun.  Pure
    /// observability, like [`ControlMsg::TraceChunk`].
    PhaseReport {
        context: ContextId,
        from: AgentId,
        profile: PhaseProfile,
    },
}

/// One agent's live state at a window boundary (the payload of
/// [`ControlMsg::Telemetry`]): virtual progress (LVT, executed windows),
/// the adaptive window-budget trajectory, writer-queue occupancy, wire
/// traffic, and pending event-queue depth.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TelemetrySnapshot {
    /// Executed safe windows at emission time.
    pub windows: u64,
    /// Local virtual time in seconds.
    pub lvt_s: f64,
    /// Window budget (events per window) in force at emission.
    pub budget: u64,
    /// Writer-queue occupancy: frames currently queued across peers.
    pub queue_depth: u64,
    /// Writer-queue highwater mark since the run started.
    pub queue_highwater: u64,
    /// Cumulative wire bytes sent.
    pub wire_bytes: u64,
    /// Cumulative wire frames sent.
    pub wire_frames: u64,
    /// Pending event-queue depth (local + remote events).
    pub events_queued: u64,
    /// Host 1-minute load average at emission (display-only: folded into
    /// `--watch` next to LVT lag; 0 when host sampling is unavailable).
    pub cpu_load: f64,
    /// Host memory-used fraction in `[0, 1]` (display-only).
    pub mem_used: f64,
    /// Last leader round-trip estimate in milliseconds (display-only).
    pub rtt_ms: f64,
}

impl TelemetrySnapshot {
    /// Report-side serialization (results files, `--results` JSON).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("windows", Json::num(self.windows as f64)),
            ("lvt_s", Json::num(self.lvt_s)),
            ("budget", Json::num(self.budget as f64)),
            ("queue_depth", Json::num(self.queue_depth as f64)),
            ("queue_highwater", Json::num(self.queue_highwater as f64)),
            ("wire_bytes", Json::num(self.wire_bytes as f64)),
            ("wire_frames", Json::num(self.wire_frames as f64)),
            ("events_queued", Json::num(self.events_queued as f64)),
            ("cpu_load", Json::num(self.cpu_load)),
            ("mem_used", Json::num(self.mem_used)),
            ("rtt_ms", Json::num(self.rtt_ms)),
        ])
    }
}

/// Everything that can travel between agents.
#[derive(Clone, Debug)]
pub enum NetMsg<P> {
    /// A simulation event, carrying the sender's current per-destination
    /// safe bound as a piggybacked null message (classic CMB optimization:
    /// every event refreshes the receiver's LVT-queue entry for free).
    /// Pre-batch frame: still accepted, and emitted when wire batching is
    /// off.
    Event {
        context: ContextId,
        event: Event<P>,
        bound: SimTime,
    },
    /// One window's traffic to one peer in a single frame: the window's
    /// events for that peer (in emission order), its sync flush, the
    /// flush's object-space replication ops, and the sender's post-window
    /// promise.  The receiver ingests space ops (context-free, versioned
    /// LWW — their order against events is immaterial), then events, then
    /// sync, then the bound — so the single trailing promise can never
    /// undercut anything in its own frame.  `bound` is `None` on non-final
    /// chunks of a size-split batch (which also carry no sync and no
    /// space ops).
    WindowBatch {
        context: ContextId,
        from: AgentId,
        events: Vec<Event<P>>,
        sync: Vec<SyncMsg>,
        /// Object-space replication folded into the per-peer frame
        /// (previously one `NetMsg::Space` frame per op per peer).
        /// Space ops are context-free and applied even when the receiver
        /// does not host `context`.
        space: Vec<SpaceMsg>,
        bound: Option<SimTime>,
    },
    Sync {
        context: ContextId,
        from: AgentId,
        msg: SyncMsg,
    },
    /// Standalone space replication op (legacy / wire batching off).
    Space(SpaceMsg),
    Control(ControlMsg),
}

// ---------------------------------------------------------------------------
// Transport trait
// ---------------------------------------------------------------------------

/// A bidirectional, FIFO-per-channel message fabric for one agent.
pub trait Transport<P>: Send {
    /// This endpoint's agent id.
    fn me(&self) -> AgentId;

    /// All agents reachable (including self).
    fn agents(&self) -> Vec<AgentId>;

    /// Send a message to one agent.
    fn send(&self, to: AgentId, msg: NetMsg<P>) -> Result<()>;

    /// Receive the next message for this agent, waiting up to `timeout`.
    fn recv_timeout(&self, timeout: Duration) -> Option<NetMsg<P>>;

    /// Non-blocking drain of everything currently queued.
    fn drain(&self) -> Vec<NetMsg<P>> {
        let mut out = Vec::new();
        while let Some(m) = self.recv_timeout(Duration::ZERO) {
            out.push(m);
        }
        out
    }

    /// Send to every other agent.
    fn broadcast(&self, msg: NetMsg<P>) -> Result<()>
    where
        P: Clone,
    {
        for a in self.agents() {
            if a != self.me() {
                self.send(a, msg.clone())?;
            }
        }
        Ok(())
    }

    /// Cumulative encoded bytes this endpoint has put on the wire (frame
    /// bodies plus length prefixes and preambles).  Endpoints that move
    /// values without serializing report 0 unless byte accounting is
    /// enabled ([`InProcNetwork::with_wire_accounting`]).  On TCP the
    /// counter advances when the writer thread transmits, so frames still
    /// queued are not yet counted (best-effort at teardown).
    fn wire_bytes(&self) -> u64 {
        0
    }

    /// Writer-queue backpressure telemetry: the wire-side input of the
    /// adaptive window controller (`coordinator::WindowController`) and
    /// the operator's compute-bound-vs-wire-bound signal.  All counters
    /// (no wall-clock reads on this path; the block-time counter is
    /// accumulated by the blocked sender itself).  Transports that
    /// deliver without queueing — in-process channels — report the
    /// default all-zero snapshot.
    fn telemetry(&self) -> TransportTelemetry {
        TransportTelemetry::default()
    }

    /// Drain fatal transport failures observed since the last call: a
    /// per-peer writer thread that died (connect failure, double write
    /// failure, undeliverable frame) or an inbound connection poisoned by
    /// a skipped sync-bearing frame.  A non-empty result means this
    /// endpoint can no longer uphold FIFO delivery — the run must abort,
    /// not stall.  Transports without failure modes return nothing.
    fn take_failures(&self) -> Vec<TransportFailure> {
        Vec::new()
    }
}

/// A fatal, unrecoverable fault on one endpoint's wire (see
/// [`Transport::take_failures`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TransportFailure {
    /// The peer whose channel died, when attributable (writer deaths are;
    /// inbound reader faults are anonymous until decoded).
    pub peer: Option<AgentId>,
    /// Human-readable first cause.
    pub reason: String,
}

impl std::fmt::Display for TransportFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.peer {
            Some(p) => write!(f, "peer {}: {}", p.raw(), self.reason),
            None => write!(f, "{}", self.reason),
        }
    }
}

/// Snapshot of an endpoint's writer-queue backpressure counters (see
/// [`Transport::telemetry`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TransportTelemetry {
    /// Configured per-peer writer-queue bound, in frames (0 = the
    /// transport has no writer queues).
    pub queue_depth: u64,
    /// Frames currently queued, max across peers.
    pub queue_occupancy: u64,
    /// Highest occupancy ever observed (capped at `queue_depth`).
    pub queue_highwater: u64,
    /// Cumulative microseconds senders have spent blocked on a full
    /// writer queue (backpressure stalls).
    pub send_block_us: u64,
    /// Adaptive-depth doubling steps taken across all writer queues
    /// (0 under a fixed [`WriterQueue`] policy).
    pub queue_grows: u64,
    /// Adaptive-depth halving steps taken across all writer queues once
    /// occupancy high-water subsided (0 under a fixed policy).
    pub queue_shrinks: u64,
    /// Oversized inbound frames skipped (drained and discarded) by this
    /// endpoint's readers.  Non-zero is always worth investigating: a
    /// skipped data-plane frame is connection-fatal, and even a skipped
    /// control/space frame means a peer's `max_frame` disagrees with ours.
    pub frames_skipped: u64,
}

// ---------------------------------------------------------------------------
// In-process transport
// ---------------------------------------------------------------------------

/// Measures what a message *would* cost on the wire (in-proc accounting).
type WireMeter<P> = Arc<dyn Fn(&NetMsg<P>) -> u64 + Send + Sync>;

struct InProcShared<P> {
    inboxes: RwLock<HashMap<AgentId, Sender<NetMsg<P>>>>,
    /// Per-sender delivery counters (message-count metrics for benches).
    sent: Mutex<HashMap<AgentId, u64>>,
    /// Optional wire-byte meter (see [`InProcNetwork::with_wire_accounting`]).
    meter: Option<WireMeter<P>>,
}

/// Factory for a set of connected in-process endpoints.
pub struct InProcNetwork<P> {
    shared: Arc<InProcShared<P>>,
}

impl<P: Send + 'static> InProcNetwork<P> {
    pub fn new() -> Self {
        Self::with_meter(None)
    }

    fn with_meter(meter: Option<WireMeter<P>>) -> Self {
        InProcNetwork {
            shared: Arc::new(InProcShared {
                inboxes: RwLock::new(HashMap::new()),
                sent: Mutex::new(HashMap::new()),
                meter,
            }),
        }
    }

    /// Create the endpoint for `agent`.  Panics if the id is taken.
    pub fn endpoint(&self, agent: AgentId) -> InProcEndpoint<P> {
        let (tx, rx) = channel();
        let mut inboxes = self.shared.inboxes.write().unwrap();
        assert!(
            inboxes.insert(agent, tx).is_none(),
            "duplicate agent {agent}"
        );
        InProcEndpoint {
            me: agent,
            shared: Arc::clone(&self.shared),
            inbox: Mutex::new(rx),
            wire_bytes: AtomicU64::new(0),
        }
    }

    /// Total messages sent through the fabric (all endpoints).
    pub fn total_sent(&self) -> u64 {
        self.shared.sent.lock().unwrap().values().sum()
    }
}

impl<P: Wire + Send + 'static> InProcNetwork<P> {
    /// A fabric with **wire-byte accounting**: every send is additionally
    /// encoded with `codec` (result discarded) purely to measure the
    /// bytes a TCP deployment would emit — frame body plus the 4-byte
    /// length prefix.  Off by default, since the measurement costs one
    /// encode per send; benches use it for codec byte comparisons on
    /// runs that never touch a socket.
    pub fn with_wire_accounting(codec: WireCodec) -> Self {
        Self::with_meter(Some(Arc::new(move |m: &NetMsg<P>| {
            encode_msg(codec, m).len() as u64 + 4
        })))
    }
}

impl<P: Send + 'static> Default for InProcNetwork<P> {
    fn default() -> Self {
        Self::new()
    }
}

/// One agent's endpoint on an [`InProcNetwork`].
pub struct InProcEndpoint<P> {
    me: AgentId,
    shared: Arc<InProcShared<P>>,
    inbox: Mutex<Receiver<NetMsg<P>>>,
    /// Metered bytes (0 unless the fabric has wire accounting).
    wire_bytes: AtomicU64,
}

impl<P: Send + 'static> Transport<P> for InProcEndpoint<P> {
    fn me(&self) -> AgentId {
        self.me
    }

    fn agents(&self) -> Vec<AgentId> {
        let mut v: Vec<AgentId> = self.shared.inboxes.read().unwrap().keys().copied().collect();
        v.sort();
        v
    }

    fn send(&self, to: AgentId, msg: NetMsg<P>) -> Result<()> {
        let inboxes = self.shared.inboxes.read().unwrap();
        let tx = inboxes
            .get(&to)
            .ok_or_else(|| anyhow!("unknown agent {to}"))?;
        if let Some(meter) = &self.shared.meter {
            self.wire_bytes.fetch_add(meter(&msg), Ordering::Relaxed);
        }
        tx.send(msg).map_err(|_| anyhow!("agent {to} hung up"))?;
        *self.shared.sent.lock().unwrap().entry(self.me).or_insert(0) += 1;
        Ok(())
    }

    fn recv_timeout(&self, timeout: Duration) -> Option<NetMsg<P>> {
        let rx = self.inbox.lock().unwrap();
        if timeout.is_zero() {
            rx.try_recv().ok()
        } else {
            rx.recv_timeout(timeout).ok()
        }
    }

    fn wire_bytes(&self) -> u64 {
        self.wire_bytes.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// Wire encoding (TCP mode)
// ---------------------------------------------------------------------------

/// Frame body encoding, selected by the sender per connection (see the
/// module docs for the full format specification).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum WireCodec {
    /// Compact binary (default): varint ids, raw-bit f64, no text.
    #[default]
    Binary,
    /// JSON text — byte-compatible with pre-codec fleets (no preamble),
    /// readable on the wire; the interop/debug fallback.
    Json,
}

impl WireCodec {
    /// Preamble codec tag.
    pub fn tag(self) -> u8 {
        match self {
            WireCodec::Json => 0,
            WireCodec::Binary => 1,
        }
    }

    pub fn from_tag(tag: u8) -> Option<WireCodec> {
        match tag {
            0 => Some(WireCodec::Json),
            1 => Some(WireCodec::Binary),
            _ => None,
        }
    }
}

impl std::fmt::Display for WireCodec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireCodec::Binary => write!(f, "binary"),
            WireCodec::Json => write!(f, "json"),
        }
    }
}

impl std::str::FromStr for WireCodec {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "binary" | "bin" => Ok(WireCodec::Binary),
            "json" | "text" => Ok(WireCodec::Json),
            other => Err(format!("unknown wire codec '{other}' (binary|json)")),
        }
    }
}

/// Connection preamble magic.  Chosen so it can never be mistaken for a
/// frame length prefix: as a u32 BE length it would claim a >1 GiB frame,
/// far beyond any accepted limit.
pub const WIRE_MAGIC: [u8; 4] = *b"DSIM";

/// Bump on any incompatible change to an *existing* binary field layout
/// (new message kinds take new tags instead; see module docs).
pub const WIRE_VERSION: u8 = 1;

/// Wire-encodable payloads (needed only for the TCP transport and byte
/// accounting; the in-process transport moves values directly).  JSON is
/// the mandatory base form; the binary form defaults to bridging through
/// the JSON tree — still raw-bit f64, no text — and hot payload types
/// override it with a dedicated tag+fields encoding.
pub trait Wire: Sized {
    fn to_json(&self) -> Json;
    fn from_json(j: &Json) -> Result<Self>;

    /// Append this value's binary wire form.
    fn encode_bin(&self, out: &mut Vec<u8>) {
        self.to_json().encode_bin(out);
    }

    /// Decode one value produced by [`encode_bin`](Self::encode_bin).
    fn decode_bin(r: &mut bin::Reader) -> Result<Self> {
        let j = Json::decode_bin(r)?;
        Self::from_json(&j)
    }
}

impl Wire for u32 {
    fn to_json(&self) -> Json {
        Json::num(*self as f64)
    }
    fn from_json(j: &Json) -> Result<Self> {
        j.as_u64()
            .map(|v| v as u32)
            .ok_or_else(|| anyhow!("expected number"))
    }
    fn encode_bin(&self, out: &mut Vec<u8>) {
        bin::put_u64(out, *self as u64);
    }
    fn decode_bin(r: &mut bin::Reader) -> Result<Self> {
        let v = r.u64()?;
        u32::try_from(v).map_err(|_| anyhow!("u32 payload out of range: {v}"))
    }
}

pub(crate) fn time_to_json(t: SimTime) -> Json {
    if t.0 == f64::INFINITY {
        Json::str("inf")
    } else if t.0 == f64::NEG_INFINITY {
        Json::str("-inf")
    } else {
        Json::num(t.0)
    }
}

pub(crate) fn time_from_json(j: &Json) -> Result<SimTime> {
    match j {
        Json::Num(n) => Ok(SimTime::new(*n)),
        Json::Str(s) if s == "inf" => Ok(SimTime::INF),
        Json::Str(s) if s == "-inf" => Ok(SimTime::NEG_INF),
        _ => bail!("bad time {j}"),
    }
}

pub(crate) fn event_to_json<P: Wire>(e: &Event<P>) -> Json {
    Json::obj(vec![
        ("t", time_to_json(e.time)),
        ("tie0", Json::num(e.tie.0 as f64)),
        ("tie1", Json::num(e.tie.1 as f64)),
        ("sa", Json::num(e.src_agent.raw() as f64)),
        ("sl", Json::num(e.src_lp.raw() as f64)),
        ("dl", Json::num(e.dst_lp.raw() as f64)),
        ("p", e.payload.to_json()),
    ])
}

pub(crate) fn event_from_json<P: Wire>(j: &Json) -> Result<Event<P>> {
    Ok(Event {
        time: time_from_json(j.get("t").context("t")?)?,
        tie: (
            j.get("tie0").and_then(Json::as_u64).context("tie0")?,
            j.get("tie1").and_then(Json::as_u64).context("tie1")?,
        ),
        src_agent: AgentId(j.get("sa").and_then(Json::as_u64).context("sa")?),
        src_lp: LpId(j.get("sl").and_then(Json::as_u64).context("sl")?),
        dst_lp: LpId(j.get("dl").and_then(Json::as_u64).context("dl")?),
        payload: P::from_json(j.get("p").context("p")?)?,
    })
}

fn sync_to_json(m: &SyncMsg) -> Json {
    match m {
        SyncMsg::LvtRequest { need, lvt } => Json::obj(vec![
            ("k", Json::str("req")),
            ("need", time_to_json(*need)),
            ("lvt", time_to_json(*lvt)),
        ]),
        SyncMsg::LvtAnnounce { bound } => Json::obj(vec![
            ("k", Json::str("ann")),
            ("bound", time_to_json(*bound)),
        ]),
    }
}

fn sync_from_json(j: &Json) -> Result<SyncMsg> {
    match j.get("k").and_then(Json::as_str) {
        Some("req") => Ok(SyncMsg::LvtRequest {
            need: time_from_json(j.get("need").context("need")?)?,
            lvt: time_from_json(j.get("lvt").context("lvt")?)?,
        }),
        Some("ann") => Ok(SyncMsg::LvtAnnounce {
            bound: time_from_json(j.get("bound").context("bound")?)?,
        }),
        _ => bail!("bad sync msg {j}"),
    }
}

fn control_to_json(c: &ControlMsg) -> Json {
    use ControlMsg::*;
    match c {
        DeployLp {
            context,
            lp,
            kind,
            params,
        } => Json::obj(vec![
            ("k", Json::str("deploy")),
            ("ctx", Json::num(context.raw() as f64)),
            ("lp", Json::num(lp.raw() as f64)),
            ("kind", Json::str(kind.clone())),
            ("params", params.clone()),
        ]),
        RoutingTable { context, routes } => Json::obj(vec![
            ("k", Json::str("routes")),
            ("ctx", Json::num(context.raw() as f64)),
            (
                "routes",
                Json::arr(routes.iter().map(|(l, a)| {
                    Json::arr([Json::num(l.raw() as f64), Json::num(a.raw() as f64)])
                })),
            ),
        ]),
        Bootstrap {
            context,
            time,
            dst,
            payload,
        } => Json::obj(vec![
            ("k", Json::str("bootstrap")),
            ("ctx", Json::num(context.raw() as f64)),
            ("t", time_to_json(*time)),
            ("dst", Json::num(dst.raw() as f64)),
            ("p", payload.clone()),
        ]),
        StartRun {
            context,
            participants,
        } => Json::obj(vec![
            ("k", Json::str("start")),
            ("ctx", Json::num(context.raw() as f64)),
            (
                "parts",
                Json::arr(participants.iter().map(|a| Json::num(a.raw() as f64))),
            ),
        ]),
        Probe { context, round } => Json::obj(vec![
            ("k", Json::str("probe")),
            ("ctx", Json::num(context.raw() as f64)),
            ("round", Json::num(*round as f64)),
        ]),
        ProbeReply {
            context,
            round,
            from,
            idle,
            sent,
            received,
            lvt,
            next_event,
            windows,
        } => Json::obj(vec![
            ("k", Json::str("probe-reply")),
            ("ctx", Json::num(context.raw() as f64)),
            ("round", Json::num(*round as f64)),
            ("from", Json::num(from.raw() as f64)),
            ("idle", Json::Bool(*idle)),
            ("sent", Json::num(*sent as f64)),
            ("received", Json::num(*received as f64)),
            ("lvt", time_to_json(*lvt)),
            ("next", time_to_json(*next_event)),
            ("win", Json::num(*windows as f64)),
        ]),
        GvtUpdate { context, gvt } => Json::obj(vec![
            ("k", Json::str("gvt")),
            ("ctx", Json::num(context.raw() as f64)),
            ("gvt", time_to_json(*gvt)),
        ]),
        EndRun { context } => Json::obj(vec![
            ("k", Json::str("end")),
            ("ctx", Json::num(context.raw() as f64)),
        ]),
        FinalStats {
            context,
            from,
            stats,
        } => Json::obj(vec![
            ("k", Json::str("stats")),
            ("ctx", Json::num(context.raw() as f64)),
            ("from", Json::num(from.raw() as f64)),
            ("stats", stats.to_json()),
        ]),
        Result {
            context,
            kind,
            record,
        } => Json::obj(vec![
            ("k", Json::str("result")),
            ("ctx", Json::num(context.raw() as f64)),
            ("kind", Json::str(kind.clone())),
            ("record", record.clone()),
        ]),
        WindowReport {
            context,
            from,
            windows,
            records,
        } => Json::obj(vec![
            ("k", Json::str("wreport")),
            ("ctx", Json::num(context.raw() as f64)),
            ("from", Json::num(from.raw() as f64)),
            ("win", Json::num(*windows as f64)),
            (
                "recs",
                Json::arr(records.iter().map(|(kind, record)| {
                    Json::arr([Json::str(kind.clone()), record.clone()])
                })),
            ),
        ]),
        PerfSample { from, value, load } => Json::obj(vec![
            ("k", Json::str("perf")),
            ("from", Json::num(from.raw() as f64)),
            ("value", Json::num(*value)),
            ("load", load.clone()),
        ]),
        Shutdown => Json::obj(vec![("k", Json::str("shutdown"))]),
        Heartbeat { from, seq } => Json::obj(vec![
            ("k", Json::str("hb")),
            ("from", Json::num(from.raw() as f64)),
            ("seq", Json::num(*seq as f64)),
        ]),
        AgentFailed { from, reason } => Json::obj(vec![
            ("k", Json::str("agent-failed")),
            ("from", Json::num(from.raw() as f64)),
            ("reason", Json::str(reason.clone())),
        ]),
        CheckpointStart { context, ckpt } => Json::obj(vec![
            ("k", Json::str("ckpt-start")),
            ("ctx", Json::num(context.raw() as f64)),
            ("ckpt", Json::num(*ckpt as f64)),
        ]),
        CheckpointReply {
            context,
            ckpt,
            from,
            sent,
            received,
        } => Json::obj(vec![
            ("k", Json::str("ckpt-reply")),
            ("ctx", Json::num(context.raw() as f64)),
            ("ckpt", Json::num(*ckpt as f64)),
            ("from", Json::num(from.raw() as f64)),
            ("sent", Json::num(*sent as f64)),
            ("received", Json::num(*received as f64)),
        ]),
        CheckpointPoll { context, ckpt } => Json::obj(vec![
            ("k", Json::str("ckpt-poll")),
            ("ctx", Json::num(context.raw() as f64)),
            ("ckpt", Json::num(*ckpt as f64)),
        ]),
        CheckpointCommit { context, ckpt } => Json::obj(vec![
            ("k", Json::str("ckpt-commit")),
            ("ctx", Json::num(context.raw() as f64)),
            ("ckpt", Json::num(*ckpt as f64)),
        ]),
        CheckpointDone {
            context,
            ckpt,
            from,
            err,
        } => Json::obj(vec![
            ("k", Json::str("ckpt-done")),
            ("ctx", Json::num(context.raw() as f64)),
            ("ckpt", Json::num(*ckpt as f64)),
            ("from", Json::num(from.raw() as f64)),
            ("err", Json::str(err.clone())),
        ]),
        Rollback { context, ckpt } => Json::obj(vec![
            ("k", Json::str("rollback")),
            ("ctx", Json::num(context.raw() as f64)),
            ("ckpt", Json::num(*ckpt as f64)),
        ]),
        RollbackDone {
            context,
            ckpt,
            from,
            err,
        } => Json::obj(vec![
            ("k", Json::str("rollback-done")),
            ("ctx", Json::num(context.raw() as f64)),
            ("ckpt", Json::num(*ckpt as f64)),
            ("from", Json::num(from.raw() as f64)),
            ("err", Json::str(err.clone())),
        ]),
        Telemetry { context, from, snap } => Json::obj(vec![
            ("k", Json::str("telem")),
            ("ctx", Json::num(context.raw() as f64)),
            ("from", Json::num(from.raw() as f64)),
            ("win", Json::num(snap.windows as f64)),
            ("lvt", Json::num(snap.lvt_s)),
            ("budget", Json::num(snap.budget as f64)),
            ("qd", Json::num(snap.queue_depth as f64)),
            ("qh", Json::num(snap.queue_highwater as f64)),
            ("wb", Json::num(snap.wire_bytes as f64)),
            ("wf", Json::num(snap.wire_frames as f64)),
            ("eq", Json::num(snap.events_queued as f64)),
            ("cpu", Json::num(snap.cpu_load)),
            ("mem", Json::num(snap.mem_used)),
            ("rtt", Json::num(snap.rtt_ms)),
        ]),
        TraceChunk {
            context,
            from,
            seq,
            dropped,
            spans,
        } => Json::obj(vec![
            ("k", Json::str("trace")),
            ("ctx", Json::num(context.raw() as f64)),
            ("from", Json::num(from.raw() as f64)),
            ("seq", Json::num(*seq as f64)),
            ("drop", Json::num(*dropped as f64)),
            ("spans", Json::arr(spans.iter().map(|s| s.to_json()))),
        ]),
        PhaseReport {
            context,
            from,
            profile,
        } => Json::obj(vec![
            ("k", Json::str("phase")),
            ("ctx", Json::num(context.raw() as f64)),
            ("from", Json::num(from.raw() as f64)),
            ("prof", profile.to_json()),
        ]),
    }
}

fn control_from_json(j: &Json) -> Result<ControlMsg> {
    let ctx = || -> Result<ContextId> {
        Ok(ContextId(j.get("ctx").and_then(Json::as_u64).context("ctx")?))
    };
    match j.get("k").and_then(Json::as_str) {
        Some("deploy") => Ok(ControlMsg::DeployLp {
            context: ctx()?,
            lp: LpId(j.get("lp").and_then(Json::as_u64).context("lp")?),
            kind: j
                .get("kind")
                .and_then(Json::as_str)
                .context("kind")?
                .to_string(),
            params: j.get("params").context("params")?.clone(),
        }),
        Some("routes") => {
            let mut routes = Vec::new();
            for r in j.get("routes").and_then(Json::as_arr).context("routes")? {
                let pair = r.as_arr().context("route pair")?;
                routes.push((
                    LpId(pair[0].as_u64().context("lp")?),
                    AgentId(pair[1].as_u64().context("agent")?),
                ));
            }
            Ok(ControlMsg::RoutingTable {
                context: ctx()?,
                routes,
            })
        }
        Some("bootstrap") => Ok(ControlMsg::Bootstrap {
            context: ctx()?,
            time: time_from_json(j.get("t").context("t")?)?,
            dst: LpId(j.get("dst").and_then(Json::as_u64).context("dst")?),
            payload: j.get("p").context("p")?.clone(),
        }),
        Some("start") => Ok(ControlMsg::StartRun {
            context: ctx()?,
            participants: j
                .get("parts")
                .and_then(Json::as_arr)
                .context("parts")?
                .iter()
                .filter_map(Json::as_u64)
                .map(AgentId)
                .collect(),
        }),
        Some("probe") => Ok(ControlMsg::Probe {
            context: ctx()?,
            round: j.get("round").and_then(Json::as_u64).context("round")?,
        }),
        Some("probe-reply") => Ok(ControlMsg::ProbeReply {
            context: ctx()?,
            round: j.get("round").and_then(Json::as_u64).context("round")?,
            from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
            idle: j.get("idle").and_then(Json::as_bool).context("idle")?,
            sent: j.get("sent").and_then(Json::as_u64).context("sent")?,
            received: j
                .get("received")
                .and_then(Json::as_u64)
                .context("received")?,
            lvt: time_from_json(j.get("lvt").context("lvt")?)?,
            next_event: time_from_json(j.get("next").context("next")?)?,
            // Absent in pre-window frames; default keeps mixed fleets
            // decoding.
            windows: j.get("win").and_then(Json::as_u64).unwrap_or(0),
        }),
        Some("gvt") => Ok(ControlMsg::GvtUpdate {
            context: ctx()?,
            gvt: time_from_json(j.get("gvt").context("gvt")?)?,
        }),
        Some("end") => Ok(ControlMsg::EndRun { context: ctx()? }),
        Some("stats") => Ok(ControlMsg::FinalStats {
            context: ctx()?,
            from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
            stats: HostStatsView::from_json(j.get("stats").context("stats")?)
                .ok_or_else(|| anyhow!("bad stats object"))?,
        }),
        Some("result") => Ok(ControlMsg::Result {
            context: ctx()?,
            kind: j
                .get("kind")
                .and_then(Json::as_str)
                .context("kind")?
                .to_string(),
            record: j.get("record").context("record")?.clone(),
        }),
        Some("wreport") => {
            let mut records = Vec::new();
            for r in j.get("recs").and_then(Json::as_arr).context("recs")? {
                let pair = r.as_arr().context("record pair")?;
                if pair.len() != 2 {
                    bail!("bad record pair {r}");
                }
                records.push((
                    pair[0].as_str().context("record kind")?.to_string(),
                    pair[1].clone(),
                ));
            }
            Ok(ControlMsg::WindowReport {
                context: ctx()?,
                from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
                windows: j.get("win").and_then(Json::as_u64).context("win")?,
                records,
            })
        }
        Some("perf") => Ok(ControlMsg::PerfSample {
            from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
            value: j.get("value").and_then(Json::as_f64).context("value")?,
            load: j.get("load").context("load")?.clone(),
        }),
        Some("shutdown") => Ok(ControlMsg::Shutdown),
        Some("hb") => Ok(ControlMsg::Heartbeat {
            from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
            seq: j.get("seq").and_then(Json::as_u64).context("seq")?,
        }),
        Some("agent-failed") => Ok(ControlMsg::AgentFailed {
            from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
            reason: j
                .get("reason")
                .and_then(Json::as_str)
                .context("reason")?
                .to_string(),
        }),
        Some("ckpt-start") => Ok(ControlMsg::CheckpointStart {
            context: ctx()?,
            ckpt: j.get("ckpt").and_then(Json::as_u64).context("ckpt")?,
        }),
        Some("ckpt-reply") => Ok(ControlMsg::CheckpointReply {
            context: ctx()?,
            ckpt: j.get("ckpt").and_then(Json::as_u64).context("ckpt")?,
            from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
            sent: j.get("sent").and_then(Json::as_u64).context("sent")?,
            received: j
                .get("received")
                .and_then(Json::as_u64)
                .context("received")?,
        }),
        Some("ckpt-poll") => Ok(ControlMsg::CheckpointPoll {
            context: ctx()?,
            ckpt: j.get("ckpt").and_then(Json::as_u64).context("ckpt")?,
        }),
        Some("ckpt-commit") => Ok(ControlMsg::CheckpointCommit {
            context: ctx()?,
            ckpt: j.get("ckpt").and_then(Json::as_u64).context("ckpt")?,
        }),
        Some("ckpt-done") => Ok(ControlMsg::CheckpointDone {
            context: ctx()?,
            ckpt: j.get("ckpt").and_then(Json::as_u64).context("ckpt")?,
            from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
            err: j
                .get("err")
                .and_then(Json::as_str)
                .context("err")?
                .to_string(),
        }),
        Some("rollback") => Ok(ControlMsg::Rollback {
            context: ctx()?,
            ckpt: j.get("ckpt").and_then(Json::as_u64).context("ckpt")?,
        }),
        Some("rollback-done") => Ok(ControlMsg::RollbackDone {
            context: ctx()?,
            ckpt: j.get("ckpt").and_then(Json::as_u64).context("ckpt")?,
            from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
            err: j
                .get("err")
                .and_then(Json::as_str)
                .context("err")?
                .to_string(),
        }),
        Some("telem") => Ok(ControlMsg::Telemetry {
            context: ctx()?,
            from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
            snap: TelemetrySnapshot {
                windows: j.get("win").and_then(Json::as_u64).context("win")?,
                lvt_s: j.get("lvt").and_then(Json::as_f64).context("lvt")?,
                budget: j.get("budget").and_then(Json::as_u64).context("budget")?,
                queue_depth: j.get("qd").and_then(Json::as_u64).context("qd")?,
                queue_highwater: j.get("qh").and_then(Json::as_u64).context("qh")?,
                wire_bytes: j.get("wb").and_then(Json::as_u64).context("wb")?,
                wire_frames: j.get("wf").and_then(Json::as_u64).context("wf")?,
                events_queued: j.get("eq").and_then(Json::as_u64).context("eq")?,
                // Absent in pre-host-sample frames; defaults keep mixed
                // fleets decoding.
                cpu_load: j.get("cpu").and_then(Json::as_f64).unwrap_or(0.0),
                mem_used: j.get("mem").and_then(Json::as_f64).unwrap_or(0.0),
                rtt_ms: j.get("rtt").and_then(Json::as_f64).unwrap_or(0.0),
            },
        }),
        Some("trace") => {
            let mut spans = Vec::new();
            for sj in j.get("spans").and_then(Json::as_arr).context("spans")? {
                spans.push(TraceSpan::from_json(sj).ok_or_else(|| anyhow!("bad span {sj}"))?);
            }
            Ok(ControlMsg::TraceChunk {
                context: ctx()?,
                from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
                seq: j.get("seq").and_then(Json::as_u64).context("seq")?,
                dropped: j.get("drop").and_then(Json::as_u64).context("drop")?,
                spans,
            })
        }
        Some("phase") => Ok(ControlMsg::PhaseReport {
            context: ctx()?,
            from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
            profile: PhaseProfile::from_json(j.get("prof").context("prof")?)
                .ok_or_else(|| anyhow!("bad phase profile"))?,
        }),
        _ => bail!("bad control msg {j}"),
    }
}

/// Full NetMsg encoding.
pub fn msg_to_json<P: Wire>(m: &NetMsg<P>) -> Json {
    match m {
        NetMsg::Event {
            context,
            event,
            bound,
        } => Json::obj(vec![
            ("k", Json::str("event")),
            ("ctx", Json::num(context.raw() as f64)),
            ("ev", event_to_json(event)),
            ("b", time_to_json(*bound)),
        ]),
        NetMsg::WindowBatch {
            context,
            from,
            events,
            sync,
            space,
            bound,
        } => {
            let mut fields = vec![
                ("k", Json::str("batch")),
                ("ctx", Json::num(context.raw() as f64)),
                ("from", Json::num(from.raw() as f64)),
                ("evs", Json::arr(events.iter().map(event_to_json))),
                ("sync", Json::arr(sync.iter().map(sync_to_json))),
            ];
            // Absent keys keep pre-space and pre-codec frames decoding.
            if !space.is_empty() {
                fields.push(("sp", Json::arr(space.iter().map(|op| op.to_json()))));
            }
            // Absent key = no promise (non-final split chunk).
            if let Some(b) = bound {
                fields.push(("b", time_to_json(*b)));
            }
            Json::obj(fields)
        }
        NetMsg::Sync { context, from, msg } => Json::obj(vec![
            ("k", Json::str("sync")),
            ("ctx", Json::num(context.raw() as f64)),
            ("from", Json::num(from.raw() as f64)),
            ("msg", sync_to_json(msg)),
        ]),
        NetMsg::Space(op) => Json::obj(vec![("k", Json::str("space")), ("op", op.to_json())]),
        NetMsg::Control(c) => {
            Json::obj(vec![("k", Json::str("control")), ("c", control_to_json(c))])
        }
    }
}

pub fn msg_from_json<P: Wire>(j: &Json) -> Result<NetMsg<P>> {
    match j.get("k").and_then(Json::as_str) {
        Some("event") => Ok(NetMsg::Event {
            context: ContextId(j.get("ctx").and_then(Json::as_u64).context("ctx")?),
            event: event_from_json(j.get("ev").context("ev")?)?,
            bound: time_from_json(j.get("b").context("b")?)?,
        }),
        Some("batch") => {
            let mut events = Vec::new();
            for e in j.get("evs").and_then(Json::as_arr).context("evs")? {
                events.push(event_from_json(e)?);
            }
            let mut sync = Vec::new();
            for s in j.get("sync").and_then(Json::as_arr).context("sync")? {
                sync.push(sync_from_json(s)?);
            }
            // Absent in pre-space frames: no replication ops.
            let mut space = Vec::new();
            if let Some(sp) = j.get("sp") {
                for op in sp.as_arr().context("sp")? {
                    space.push(SpaceMsg::from_json(op)?);
                }
            }
            Ok(NetMsg::WindowBatch {
                context: ContextId(j.get("ctx").and_then(Json::as_u64).context("ctx")?),
                from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
                events,
                sync,
                space,
                bound: match j.get("b") {
                    Some(b) => Some(time_from_json(b)?),
                    None => None,
                },
            })
        }
        Some("sync") => Ok(NetMsg::Sync {
            context: ContextId(j.get("ctx").and_then(Json::as_u64).context("ctx")?),
            from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
            msg: sync_from_json(j.get("msg").context("msg")?)?,
        }),
        Some("space") => Ok(NetMsg::Space(SpaceMsg::from_json(j.get("op").context("op")?)?)),
        Some("control") => Ok(NetMsg::Control(control_from_json(
            j.get("c").context("c")?,
        )?)),
        _ => bail!("bad net msg {j}"),
    }
}

// ---------------------------------------------------------------------------
// Binary codec (format spec in the module docs)
// ---------------------------------------------------------------------------

/// Decode-side pre-allocation ceiling for vec counts.  `len_prefix`
/// bounds a count by the *bytes* remaining, but elements can be far
/// larger in memory than on the wire — capping the reserved capacity
/// keeps a hostile count from amplifying a 64 MiB frame into a multi-GiB
/// allocation; genuine larger vecs just grow amortized past the hint.
const CAP_HINT: usize = 1024;

fn put_time(out: &mut Vec<u8>, t: SimTime) {
    bin::put_f64(out, t.secs());
}

fn get_time(r: &mut bin::Reader) -> Result<SimTime> {
    let v = r.f64()?;
    if v.is_nan() {
        bail!("NaN timestamp on the wire");
    }
    Ok(SimTime::new(v))
}

fn event_to_bin<P: Wire>(out: &mut Vec<u8>, e: &Event<P>) {
    put_time(out, e.time);
    bin::put_u64(out, e.tie.0);
    bin::put_u64(out, e.tie.1);
    bin::put_u64(out, e.src_agent.raw());
    bin::put_u64(out, e.src_lp.raw());
    bin::put_u64(out, e.dst_lp.raw());
    e.payload.encode_bin(out);
}

fn event_from_bin<P: Wire>(r: &mut bin::Reader) -> Result<Event<P>> {
    Ok(Event {
        time: get_time(r)?,
        tie: (r.u64()?, r.u64()?),
        src_agent: AgentId(r.u64()?),
        src_lp: LpId(r.u64()?),
        dst_lp: LpId(r.u64()?),
        payload: P::decode_bin(r)?,
    })
}

fn sync_to_bin(out: &mut Vec<u8>, m: &SyncMsg) {
    match m {
        SyncMsg::LvtRequest { need, lvt } => {
            out.push(1);
            put_time(out, *need);
            put_time(out, *lvt);
        }
        SyncMsg::LvtAnnounce { bound } => {
            out.push(2);
            put_time(out, *bound);
        }
    }
}

fn sync_from_bin(r: &mut bin::Reader) -> Result<SyncMsg> {
    match r.u8()? {
        1 => Ok(SyncMsg::LvtRequest {
            need: get_time(r)?,
            lvt: get_time(r)?,
        }),
        2 => Ok(SyncMsg::LvtAnnounce { bound: get_time(r)? }),
        t => bail!("bad sync tag {t}"),
    }
}

fn space_to_bin(out: &mut Vec<u8>, m: &SpaceMsg) {
    match m {
        SpaceMsg::Write(e) => {
            out.push(1);
            bin::put_str(out, &e.key);
            e.fields.encode_bin(out);
            bin::put_u64(out, e.version);
            bin::put_u64(out, e.writer.raw());
        }
        SpaceMsg::Remove { key, version } => {
            out.push(2);
            bin::put_str(out, key);
            bin::put_u64(out, *version);
        }
    }
}

fn space_from_bin(r: &mut bin::Reader) -> Result<SpaceMsg> {
    match r.u8()? {
        1 => Ok(SpaceMsg::Write(crate::space::Entry {
            key: r.str()?,
            fields: Json::decode_bin(r)?,
            version: r.u64()?,
            writer: AgentId(r.u64()?),
        })),
        2 => Ok(SpaceMsg::Remove {
            key: r.str()?,
            version: r.u64()?,
        }),
        t => bail!("bad space tag {t}"),
    }
}

fn control_to_bin(out: &mut Vec<u8>, c: &ControlMsg) {
    use ControlMsg::*;
    match c {
        DeployLp {
            context,
            lp,
            kind,
            params,
        } => {
            out.push(1);
            bin::put_u64(out, context.raw());
            bin::put_u64(out, lp.raw());
            bin::put_str(out, kind);
            params.encode_bin(out);
        }
        RoutingTable { context, routes } => {
            out.push(2);
            bin::put_u64(out, context.raw());
            bin::put_u64(out, routes.len() as u64);
            for (lp, agent) in routes {
                bin::put_u64(out, lp.raw());
                bin::put_u64(out, agent.raw());
            }
        }
        Bootstrap {
            context,
            time,
            dst,
            payload,
        } => {
            out.push(3);
            bin::put_u64(out, context.raw());
            put_time(out, *time);
            bin::put_u64(out, dst.raw());
            payload.encode_bin(out);
        }
        StartRun {
            context,
            participants,
        } => {
            out.push(4);
            bin::put_u64(out, context.raw());
            bin::put_u64(out, participants.len() as u64);
            for a in participants {
                bin::put_u64(out, a.raw());
            }
        }
        Probe { context, round } => {
            out.push(5);
            bin::put_u64(out, context.raw());
            bin::put_u64(out, *round);
        }
        ProbeReply {
            context,
            round,
            from,
            idle,
            sent,
            received,
            lvt,
            next_event,
            windows,
        } => {
            out.push(6);
            bin::put_u64(out, context.raw());
            bin::put_u64(out, *round);
            bin::put_u64(out, from.raw());
            bin::put_bool(out, *idle);
            bin::put_u64(out, *sent);
            bin::put_u64(out, *received);
            put_time(out, *lvt);
            put_time(out, *next_event);
            bin::put_u64(out, *windows);
        }
        GvtUpdate { context, gvt } => {
            out.push(7);
            bin::put_u64(out, context.raw());
            put_time(out, *gvt);
        }
        EndRun { context } => {
            out.push(8);
            bin::put_u64(out, context.raw());
        }
        FinalStats {
            context,
            from,
            stats,
        } => {
            out.push(9);
            bin::put_u64(out, context.raw());
            bin::put_u64(out, from.raw());
            // Bridge through the JSON tree: byte-identical to the
            // pre-typed frames, so no WIRE_VERSION bump is needed.
            stats.to_json().encode_bin(out);
        }
        Result {
            context,
            kind,
            record,
        } => {
            out.push(10);
            bin::put_u64(out, context.raw());
            bin::put_str(out, kind);
            record.encode_bin(out);
        }
        WindowReport {
            context,
            from,
            windows,
            records,
        } => {
            out.push(11);
            bin::put_u64(out, context.raw());
            bin::put_u64(out, from.raw());
            bin::put_u64(out, *windows);
            bin::put_u64(out, records.len() as u64);
            for (kind, record) in records {
                bin::put_str(out, kind);
                record.encode_bin(out);
            }
        }
        PerfSample { from, value, load } => {
            out.push(12);
            bin::put_u64(out, from.raw());
            bin::put_f64(out, *value);
            load.encode_bin(out);
        }
        Shutdown => out.push(13),
        Heartbeat { from, seq } => {
            out.push(14);
            bin::put_u64(out, from.raw());
            bin::put_u64(out, *seq);
        }
        AgentFailed { from, reason } => {
            out.push(15);
            bin::put_u64(out, from.raw());
            bin::put_str(out, reason);
        }
        CheckpointStart { context, ckpt } => {
            out.push(16);
            bin::put_u64(out, context.raw());
            bin::put_u64(out, *ckpt);
        }
        CheckpointReply {
            context,
            ckpt,
            from,
            sent,
            received,
        } => {
            out.push(17);
            bin::put_u64(out, context.raw());
            bin::put_u64(out, *ckpt);
            bin::put_u64(out, from.raw());
            bin::put_u64(out, *sent);
            bin::put_u64(out, *received);
        }
        CheckpointPoll { context, ckpt } => {
            out.push(18);
            bin::put_u64(out, context.raw());
            bin::put_u64(out, *ckpt);
        }
        CheckpointCommit { context, ckpt } => {
            out.push(19);
            bin::put_u64(out, context.raw());
            bin::put_u64(out, *ckpt);
        }
        CheckpointDone {
            context,
            ckpt,
            from,
            err,
        } => {
            out.push(20);
            bin::put_u64(out, context.raw());
            bin::put_u64(out, *ckpt);
            bin::put_u64(out, from.raw());
            bin::put_str(out, err);
        }
        Rollback { context, ckpt } => {
            out.push(21);
            bin::put_u64(out, context.raw());
            bin::put_u64(out, *ckpt);
        }
        RollbackDone {
            context,
            ckpt,
            from,
            err,
        } => {
            out.push(22);
            bin::put_u64(out, context.raw());
            bin::put_u64(out, *ckpt);
            bin::put_u64(out, from.raw());
            bin::put_str(out, err);
        }
        Telemetry { context, from, snap } => {
            out.push(23);
            bin::put_u64(out, context.raw());
            bin::put_u64(out, from.raw());
            bin::put_u64(out, snap.windows);
            bin::put_f64(out, snap.lvt_s);
            bin::put_u64(out, snap.budget);
            bin::put_u64(out, snap.queue_depth);
            bin::put_u64(out, snap.queue_highwater);
            bin::put_u64(out, snap.wire_bytes);
            bin::put_u64(out, snap.wire_frames);
            bin::put_u64(out, snap.events_queued);
            bin::put_f64(out, snap.cpu_load);
            bin::put_f64(out, snap.mem_used);
            bin::put_f64(out, snap.rtt_ms);
        }
        TraceChunk {
            context,
            from,
            seq,
            dropped,
            spans,
        } => {
            out.push(24);
            bin::put_u64(out, context.raw());
            bin::put_u64(out, from.raw());
            bin::put_u64(out, *seq);
            bin::put_u64(out, *dropped);
            bin::put_u64(out, spans.len() as u64);
            for s in spans {
                out.push(s.kind as u8);
                bin::put_f64(out, s.t_s);
                bin::put_f64(out, s.dur_s);
                bin::put_u64(out, s.lp);
                bin::put_u64(out, s.aux);
            }
        }
        PhaseReport {
            context,
            from,
            profile,
        } => {
            out.push(25);
            bin::put_u64(out, context.raw());
            bin::put_u64(out, from.raw());
            // Bridge through the JSON tree, like FinalStats: one frame per
            // run, so compactness does not matter.
            profile.to_json().encode_bin(out);
        }
    }
}

fn control_from_bin(r: &mut bin::Reader) -> Result<ControlMsg> {
    let tag = r.u8()?;
    Ok(match tag {
        1 => ControlMsg::DeployLp {
            context: ContextId(r.u64()?),
            lp: LpId(r.u64()?),
            kind: r.str()?,
            params: Json::decode_bin(r)?,
        },
        2 => {
            let context = ContextId(r.u64()?);
            let n = r.len_prefix()?;
            let mut routes = Vec::with_capacity(n.min(CAP_HINT));
            for _ in 0..n {
                routes.push((LpId(r.u64()?), AgentId(r.u64()?)));
            }
            ControlMsg::RoutingTable { context, routes }
        }
        3 => ControlMsg::Bootstrap {
            context: ContextId(r.u64()?),
            time: get_time(r)?,
            dst: LpId(r.u64()?),
            payload: Json::decode_bin(r)?,
        },
        4 => {
            let context = ContextId(r.u64()?);
            let n = r.len_prefix()?;
            let mut participants = Vec::with_capacity(n.min(CAP_HINT));
            for _ in 0..n {
                participants.push(AgentId(r.u64()?));
            }
            ControlMsg::StartRun {
                context,
                participants,
            }
        }
        5 => ControlMsg::Probe {
            context: ContextId(r.u64()?),
            round: r.u64()?,
        },
        6 => ControlMsg::ProbeReply {
            context: ContextId(r.u64()?),
            round: r.u64()?,
            from: AgentId(r.u64()?),
            idle: r.bool()?,
            sent: r.u64()?,
            received: r.u64()?,
            lvt: get_time(r)?,
            next_event: get_time(r)?,
            windows: r.u64()?,
        },
        7 => ControlMsg::GvtUpdate {
            context: ContextId(r.u64()?),
            gvt: get_time(r)?,
        },
        8 => ControlMsg::EndRun {
            context: ContextId(r.u64()?),
        },
        9 => {
            let context = ContextId(r.u64()?);
            let from = AgentId(r.u64()?);
            let j = Json::decode_bin(r)?;
            ControlMsg::FinalStats {
                context,
                from,
                stats: HostStatsView::from_json(&j)
                    .ok_or_else(|| anyhow!("bad stats object"))?,
            }
        }
        10 => ControlMsg::Result {
            context: ContextId(r.u64()?),
            kind: r.str()?,
            record: Json::decode_bin(r)?,
        },
        11 => {
            let context = ContextId(r.u64()?);
            let from = AgentId(r.u64()?);
            let windows = r.u64()?;
            let n = r.len_prefix()?;
            let mut records = Vec::with_capacity(n.min(CAP_HINT));
            for _ in 0..n {
                records.push((r.str()?, Json::decode_bin(r)?));
            }
            ControlMsg::WindowReport {
                context,
                from,
                windows,
                records,
            }
        }
        12 => ControlMsg::PerfSample {
            from: AgentId(r.u64()?),
            value: r.f64()?,
            load: Json::decode_bin(r)?,
        },
        13 => ControlMsg::Shutdown,
        14 => ControlMsg::Heartbeat {
            from: AgentId(r.u64()?),
            seq: r.u64()?,
        },
        15 => ControlMsg::AgentFailed {
            from: AgentId(r.u64()?),
            reason: r.str()?,
        },
        16 => ControlMsg::CheckpointStart {
            context: ContextId(r.u64()?),
            ckpt: r.u64()?,
        },
        17 => ControlMsg::CheckpointReply {
            context: ContextId(r.u64()?),
            ckpt: r.u64()?,
            from: AgentId(r.u64()?),
            sent: r.u64()?,
            received: r.u64()?,
        },
        18 => ControlMsg::CheckpointPoll {
            context: ContextId(r.u64()?),
            ckpt: r.u64()?,
        },
        19 => ControlMsg::CheckpointCommit {
            context: ContextId(r.u64()?),
            ckpt: r.u64()?,
        },
        20 => ControlMsg::CheckpointDone {
            context: ContextId(r.u64()?),
            ckpt: r.u64()?,
            from: AgentId(r.u64()?),
            err: r.str()?,
        },
        21 => ControlMsg::Rollback {
            context: ContextId(r.u64()?),
            ckpt: r.u64()?,
        },
        22 => ControlMsg::RollbackDone {
            context: ContextId(r.u64()?),
            ckpt: r.u64()?,
            from: AgentId(r.u64()?),
            err: r.str()?,
        },
        23 => ControlMsg::Telemetry {
            context: ContextId(r.u64()?),
            from: AgentId(r.u64()?),
            snap: TelemetrySnapshot {
                windows: r.u64()?,
                lvt_s: r.f64()?,
                budget: r.u64()?,
                queue_depth: r.u64()?,
                queue_highwater: r.u64()?,
                wire_bytes: r.u64()?,
                wire_frames: r.u64()?,
                events_queued: r.u64()?,
                cpu_load: r.f64()?,
                mem_used: r.f64()?,
                rtt_ms: r.f64()?,
            },
        },
        24 => {
            let context = ContextId(r.u64()?);
            let from = AgentId(r.u64()?);
            let seq = r.u64()?;
            let dropped = r.u64()?;
            let n = r.len_prefix()?;
            let mut spans = Vec::with_capacity(n.min(CAP_HINT));
            for _ in 0..n {
                let kind = r.u8()?;
                spans.push(TraceSpan {
                    kind: SpanKind::from_u8(kind)
                        .ok_or_else(|| anyhow!("bad span kind {kind}"))?,
                    t_s: r.f64()?,
                    dur_s: r.f64()?,
                    lp: r.u64()?,
                    aux: r.u64()?,
                });
            }
            ControlMsg::TraceChunk {
                context,
                from,
                seq,
                dropped,
                spans,
            }
        }
        25 => {
            let context = ContextId(r.u64()?);
            let from = AgentId(r.u64()?);
            let j = Json::decode_bin(r)?;
            ControlMsg::PhaseReport {
                context,
                from,
                profile: PhaseProfile::from_json(&j)
                    .ok_or_else(|| anyhow!("bad phase profile"))?,
            }
        }
        t => bail!("bad control tag {t}"),
    })
}

fn msg_to_bin<P: Wire>(out: &mut Vec<u8>, m: &NetMsg<P>) {
    match m {
        NetMsg::Event {
            context,
            event,
            bound,
        } => {
            out.push(1);
            bin::put_u64(out, context.raw());
            event_to_bin(out, event);
            put_time(out, *bound);
        }
        NetMsg::WindowBatch {
            context,
            from,
            events,
            sync,
            space,
            bound,
        } => {
            out.push(2);
            bin::put_u64(out, context.raw());
            bin::put_u64(out, from.raw());
            bin::put_u64(out, events.len() as u64);
            for e in events {
                event_to_bin(out, e);
            }
            bin::put_u64(out, sync.len() as u64);
            for s in sync {
                sync_to_bin(out, s);
            }
            bin::put_u64(out, space.len() as u64);
            for op in space {
                space_to_bin(out, op);
            }
            match bound {
                Some(b) => {
                    out.push(1);
                    put_time(out, *b);
                }
                None => out.push(0),
            }
        }
        NetMsg::Sync { context, from, msg } => {
            out.push(3);
            bin::put_u64(out, context.raw());
            bin::put_u64(out, from.raw());
            sync_to_bin(out, msg);
        }
        NetMsg::Space(op) => {
            out.push(4);
            space_to_bin(out, op);
        }
        NetMsg::Control(c) => {
            out.push(5);
            control_to_bin(out, c);
        }
    }
}

fn msg_from_bin<P: Wire>(r: &mut bin::Reader) -> Result<NetMsg<P>> {
    let tag = r.u8()?;
    Ok(match tag {
        1 => NetMsg::Event {
            context: ContextId(r.u64()?),
            event: event_from_bin(r)?,
            bound: get_time(r)?,
        },
        2 => {
            let context = ContextId(r.u64()?);
            let from = AgentId(r.u64()?);
            let n = r.len_prefix()?;
            let mut events = Vec::with_capacity(n.min(CAP_HINT));
            for _ in 0..n {
                events.push(event_from_bin(r)?);
            }
            let n = r.len_prefix()?;
            let mut sync = Vec::with_capacity(n.min(CAP_HINT));
            for _ in 0..n {
                sync.push(sync_from_bin(r)?);
            }
            let n = r.len_prefix()?;
            let mut space = Vec::with_capacity(n.min(CAP_HINT));
            for _ in 0..n {
                space.push(space_from_bin(r)?);
            }
            let bound = match r.u8()? {
                0 => None,
                1 => Some(get_time(r)?),
                t => bail!("bad option tag {t}"),
            };
            NetMsg::WindowBatch {
                context,
                from,
                events,
                sync,
                space,
                bound,
            }
        }
        3 => NetMsg::Sync {
            context: ContextId(r.u64()?),
            from: AgentId(r.u64()?),
            msg: sync_from_bin(r)?,
        },
        4 => NetMsg::Space(space_from_bin(r)?),
        5 => NetMsg::Control(control_from_bin(r)?),
        t => bail!("bad net msg tag {t}"),
    })
}

/// Encode one message as a frame body under `codec`.
pub fn encode_msg<P: Wire>(codec: WireCodec, m: &NetMsg<P>) -> Vec<u8> {
    match codec {
        WireCodec::Json => msg_to_json(m).to_string().into_bytes(),
        WireCodec::Binary => {
            let mut out = Vec::with_capacity(64);
            msg_to_bin(&mut out, m);
            out
        }
    }
}

/// Decode one frame body under `codec`.  Rejects trailing bytes in binary
/// bodies (a corrupt or foreign frame, not a prefix of one).
pub fn decode_msg<P: Wire>(codec: WireCodec, bytes: &[u8]) -> Result<NetMsg<P>> {
    match codec {
        WireCodec::Json => {
            let text = std::str::from_utf8(bytes).context("frame is not utf8")?;
            msg_from_json(&Json::parse(text).map_err(anyhow::Error::from)?)
        }
        WireCodec::Binary => {
            let mut r = bin::Reader::new(bytes);
            let m = msg_from_bin(&mut r)?;
            r.finish()?;
            Ok(m)
        }
    }
}

// ---------------------------------------------------------------------------
// TCP transport
// ---------------------------------------------------------------------------

/// Default ceiling on a single length-prefixed frame, in bytes.  Window
/// batching concentrates a whole window's traffic into one frame, so the
/// default is generous; the limit exists so a corrupt length prefix can
/// never make a reader allocate gigabytes.  Configurable per endpoint via
/// [`TcpTransport::bind_with`] / `dsim agent --max-frame-mib` (the
/// `deploy.max_frame_mib` config knob records the fleet-wide value, which
/// must match on every agent); outbound `WindowBatch` frames above the
/// limit are split, inbound oversized frames are drained and skipped.
pub const DEFAULT_MAX_FRAME_BYTES: usize = 64 << 20;

/// Default bound of each per-peer writer queue, in messages.  Deep enough
/// to absorb a window's burst of batch frames; shallow enough that an
/// agent outrunning a dead-slow peer blocks (bounded memory) instead of
/// buffering without limit.  Configurable via
/// `deploy.writer_queue_frames` / `dsim agent --writer-queue-frames`.
pub const DEFAULT_WRITER_QUEUE_FRAMES: usize = 256;

/// Adaptive writer queues start this shallow (frames) and double on
/// saturation.
pub const ADAPTIVE_WRITER_QUEUE_START: usize = 16;

/// Ceiling an adaptive writer queue may grow to (frames): past this the
/// queue behaves like a fixed queue at the cap — block, never drop.
pub const ADAPTIVE_WRITER_QUEUE_MAX: usize = 4096;

/// Per-peer writer-queue sizing policy (`deploy.writer_queue_frames`).
///
/// `Fixed(N)` is the historical static bound.  `Adaptive` sizes the
/// depth from the queue's own occupancy high-water telemetry: the queue
/// starts at `start` frames and, whenever a send finds it full (the
/// high-water mark has reached the current depth), the depth doubles up
/// to `max` instead of blocking the sender — the queue self-tunes to the
/// burst size the fleet actually produces.  At `max` it blocks like a
/// fixed queue (backpressure, never loss), so the adaptive *window*
/// controller still sees saturation when the wire truly cannot keep up.
/// Growth is monotone (never shrinks) and per peer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WriterQueue {
    /// Static bound of `N` frames (>= 1).
    Fixed(usize),
    /// Grow from `start` frames by doubling on saturation, up to `max`.
    Adaptive { start: usize, max: usize },
}

impl WriterQueue {
    /// The default adaptive policy (`"adaptive"` in configs).
    pub fn adaptive() -> WriterQueue {
        WriterQueue::Adaptive {
            start: ADAPTIVE_WRITER_QUEUE_START,
            max: ADAPTIVE_WRITER_QUEUE_MAX,
        }
    }

    /// Depth a fresh queue opens with.
    pub fn initial(&self) -> usize {
        match *self {
            WriterQueue::Fixed(n) => n,
            WriterQueue::Adaptive { start, .. } => start,
        }
    }

    /// Depth the queue may never exceed.
    pub fn ceiling(&self) -> usize {
        match *self {
            WriterQueue::Fixed(n) => n,
            WriterQueue::Adaptive { max, .. } => max,
        }
    }

    /// Parse the config-file form: a plain number (fixed depth, the
    /// pre-adaptive format) or a policy string (`fixed(N)` | `adaptive`).
    /// Shared by the lenient `dsim run` config and the strict scenario
    /// loader so the two front doors can never drift.
    pub fn from_json(j: &Json) -> Result<WriterQueue, String> {
        match j {
            Json::Num(_) => {
                let n = j.as_u64().ok_or_else(|| {
                    "writer_queue_frames must be a non-negative integer or a policy string"
                        .to_string()
                })?;
                let q = WriterQueue::Fixed(n as usize);
                q.validate()?;
                Ok(q)
            }
            Json::Str(s) => s.parse(),
            _ => Err(
                "writer_queue_frames must be a number or a policy string (fixed(N) | adaptive)"
                    .to_string(),
            ),
        }
    }

    /// Reject policies a bounded queue cannot run.
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            WriterQueue::Fixed(0) => Err(
                "writer_queue_frames must be >= 1 (a bounded queue needs room for one frame)"
                    .into(),
            ),
            WriterQueue::Adaptive { start: 0, .. } => {
                Err("adaptive writer queue start depth must be >= 1".into())
            }
            WriterQueue::Adaptive { start, max } if start > max => Err(format!(
                "adaptive writer queue start ({start}) must be <= max ({max})"
            )),
            _ => Ok(()),
        }
    }
}

impl Default for WriterQueue {
    fn default() -> Self {
        WriterQueue::Fixed(DEFAULT_WRITER_QUEUE_FRAMES)
    }
}

impl std::fmt::Display for WriterQueue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WriterQueue::Fixed(n) => write!(f, "fixed({n})"),
            WriterQueue::Adaptive { .. } => write!(f, "adaptive"),
        }
    }
}

impl std::str::FromStr for WriterQueue {
    type Err = String;

    /// Accepts `adaptive`, `fixed(N)`, or a bare integer (shorthand for
    /// `fixed(N)`).
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        if s == "adaptive" {
            return Ok(WriterQueue::adaptive());
        }
        let inner = s
            .strip_prefix("fixed(")
            .and_then(|rest| rest.strip_suffix(')'))
            .unwrap_or(s);
        let n = inner.parse::<usize>().map_err(|_| {
            format!("bad writer queue '{s}' (adaptive | fixed(N) | bare frame count)")
        })?;
        let q = WriterQueue::Fixed(n);
        q.validate()?;
        Ok(q)
    }
}

/// Tuning knobs for a TCP endpoint.
#[derive(Clone, Copy, Debug)]
pub struct TcpOptions {
    /// Frame-size ceiling in bytes (see [`DEFAULT_MAX_FRAME_BYTES`]).
    pub max_frame: usize,
    /// Frame body encoding for *outbound* connections.  Inbound frames
    /// are decoded per each sender's preamble, so mixed-codec fleets
    /// interoperate in both directions.
    pub codec: WireCodec,
    /// Per-peer writer-queue sizing policy ([`WriterQueue`]).  A full
    /// queue blocks the sender — backpressure, never loss.
    pub writer_queue: WriterQueue,
    /// Total time a writer keeps retrying a refused connection before
    /// declaring the peer unreachable (`deploy.connect_timeout_ms`).
    /// Fleet members race to bind their listeners, and a launch handover
    /// re-binds a port, so refusals during startup are normal.
    pub connect_timeout: Duration,
    /// First retry delay after a refused connection
    /// (`deploy.connect_backoff_ms`); doubles per attempt, capped at 1 s.
    pub connect_backoff: Duration,
}

impl Default for TcpOptions {
    fn default() -> Self {
        TcpOptions {
            max_frame: DEFAULT_MAX_FRAME_BYTES,
            codec: WireCodec::default(),
            writer_queue: WriterQueue::default(),
            connect_timeout: Duration::from_millis(DEFAULT_CONNECT_TIMEOUT_MS),
            connect_backoff: Duration::from_millis(DEFAULT_CONNECT_BACKOFF_MS),
        }
    }
}

/// Default total connect-retry budget per peer, ms.  Covers the slowest
/// observed startup races (fleet-wide bind + launch listener handover)
/// with a wide margin.
pub const DEFAULT_CONNECT_TIMEOUT_MS: u64 = 5_000;

/// Default first connect-retry delay, ms (exponential, capped at 1 s).
pub const DEFAULT_CONNECT_BACKOFF_MS: u64 = 100;

/// Length-prefixed frame I/O.
fn write_frame(stream: &mut TcpStream, bytes: &[u8]) -> Result<()> {
    let len = (bytes.len() as u32).to_be_bytes();
    stream.write_all(&len)?;
    stream.write_all(bytes)?;
    stream.flush()?;
    Ok(())
}

/// Read one frame, enforcing `max_bytes`.  An oversized frame is drained
/// from the stream (keeping frame alignment) and reported as
/// [`ReadFrame::Skipped`] with a retained prefix, so the caller can
/// classify what was lost: the reader loop keeps the connection for a
/// dropped control/space frame and poisons it for anything data-plane
/// (see [`skipped_frame_is_fatal`] — a silently dropped `WindowBatch` can
/// swallow the window's only trailing promise and deadlock the receiver).
///
/// A skipped frame can only occur with mismatched per-agent limits (the
/// sender splits against its *own* limit) or a corrupt peer.
fn read_frame(stream: &mut TcpStream, max_bytes: usize) -> Result<ReadFrame> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len)?;
    read_frame_body(stream, len, max_bytes)
}

/// One inbound frame read: either a complete body, or an over-limit frame
/// that was drained off the stream with only a prefix retained for
/// classification (see [`skipped_frame_is_fatal`]).
enum ReadFrame {
    Frame(Vec<u8>),
    Skipped { prefix: Vec<u8>, len: usize },
}

/// How many drained bytes a skip retains: enough to classify the frame
/// kind under either codec (`{"k":"space"` is 12 bytes; binary needs 1).
const SKIP_PREFIX: usize = 16;

/// [`read_frame`] with the 4 length bytes already consumed (the preamble
/// sniff reads them to distinguish magic from a frame length).
fn read_frame_body(stream: &mut TcpStream, len: [u8; 4], max_bytes: usize) -> Result<ReadFrame> {
    let n = u32::from_be_bytes(len) as usize;
    if n > max_bytes {
        log::error!(
            "skipping oversized frame: {n} bytes > {max_bytes} limit \
             (mismatched --max-frame-mib across the fleet?)"
        );
        let mut chunk = [0u8; 8192];
        let mut remaining = n;
        let mut prefix = Vec::with_capacity(SKIP_PREFIX);
        while remaining > 0 {
            let take = remaining.min(chunk.len());
            stream.read_exact(&mut chunk[..take])?;
            if prefix.len() < SKIP_PREFIX {
                let want = (SKIP_PREFIX - prefix.len()).min(take);
                prefix.extend_from_slice(&chunk[..want]);
            }
            remaining -= take;
        }
        return Ok(ReadFrame::Skipped { prefix, len: n });
    }
    let mut buf = vec![0u8; n];
    stream.read_exact(&mut buf)?;
    Ok(ReadFrame::Frame(buf))
}

/// Classify a skipped frame by its retained prefix: dropping a `Space` op
/// (versioned LWW, resent) or a `Control` frame (the control plane has
/// its own timeouts) degrades the run but cannot wedge it, so the
/// connection survives.  Dropping an `Event`/`WindowBatch`/`Sync` frame
/// can swallow the window's only trailing promise — the receiver would
/// deadlock waiting for a bound that never arrives — so it is
/// connection-fatal.  Unrecognizable prefixes are treated as fatal.
fn skipped_frame_is_fatal(codec: WireCodec, prefix: &[u8]) -> bool {
    match codec {
        // Binary msg tags: Event=1, WindowBatch=2, Sync=3, Space=4,
        // Control=5.
        WireCodec::Binary => !matches!(prefix.first(), Some(4) | Some(5)),
        // JSON objects serialize with *sorted* keys, so each frame kind
        // has a fixed leading key: Control is `{"c":` ("c" < "k"), Space
        // is `{"k":"space"` ("k" < "op"); the data-plane frames lead with
        // `{"b":` (Event) or `{"ctx":` (WindowBatch/Sync) and the
        // hand-assembled batch chunk with `{"k":"batch"` — none collide.
        WireCodec::Json => {
            !(prefix.starts_with(b"{\"c\":") || prefix.starts_with(b"{\"k\":\"space\""))
        }
    }
}

/// Sniff a new inbound connection: a binary sender opens with
/// `WIRE_MAGIC | version | codec`; a bare stream (JSON codec, or a
/// pre-codec peer) starts directly with its first frame's length prefix,
/// which is returned as `pending` so no byte is lost.  `Ok(None)` means
/// the preamble was present but unusable (version/codec mismatch) — the
/// caller drops only this connection.
fn read_connection_codec(
    stream: &mut TcpStream,
) -> std::io::Result<Option<(WireCodec, Option<[u8; 4]>)>> {
    let mut head = [0u8; 4];
    stream.read_exact(&mut head)?;
    if head != WIRE_MAGIC {
        return Ok(Some((WireCodec::Json, Some(head))));
    }
    let mut vc = [0u8; 2];
    stream.read_exact(&mut vc)?;
    if vc[0] != WIRE_VERSION {
        log::error!(
            "dropping connection with wire version {} (this agent speaks {WIRE_VERSION}); \
             run mixed fleets with --wire-codec json",
            vc[0]
        );
        return Ok(None);
    }
    match WireCodec::from_tag(vc[1]) {
        Some(codec) => Ok(Some((codec, None))),
        None => {
            log::error!("dropping connection with unknown wire codec tag {}", vc[1]);
            Ok(None)
        }
    }
}

/// Encode `msg` under `codec`, splitting over-limit batch frames into
/// smaller chunks: a [`NetMsg::WindowBatch`] through the zero-re-encode
/// chunker ([`encode_batch_chunks`] — each event is encoded exactly once
/// and frames are sliced out of those encodings; non-final chunks carry
/// no sync flush, no space ops and no bound, so the promise stays behind
/// everything it covers), a [`ControlMsg::WindowReport`] by halving its
/// record list (the cumulative window count is idempotent).  Anything
/// else over the limit is a hard error — the receiver would drain and
/// drop it anyway.  Encoded frame bodies are appended to `out` in send
/// order.
fn encode_split<P: Wire>(
    codec: WireCodec,
    max_frame: usize,
    msg: NetMsg<P>,
    out: &mut Vec<Vec<u8>>,
) -> Result<()> {
    let body = encode_msg(codec, &msg);
    if body.len() <= max_frame {
        out.push(body);
        return Ok(());
    }
    match msg {
        NetMsg::WindowBatch {
            context,
            from,
            events,
            sync,
            space,
            bound,
        } if !events.is_empty() => encode_batch_chunks(
            codec, max_frame, context, from, events, sync, space, bound, out,
        ),
        NetMsg::Control(ControlMsg::WindowReport {
            context,
            from,
            windows,
            mut records,
        }) if records.len() > 1 => {
            let tail = records.split_off(records.len() / 2);
            encode_split(
                codec,
                max_frame,
                NetMsg::Control(ControlMsg::WindowReport {
                    context,
                    from,
                    windows,
                    records,
                }),
                out,
            )?;
            encode_split(
                codec,
                max_frame,
                NetMsg::Control(ControlMsg::WindowReport {
                    context,
                    from,
                    windows,
                    records: tail,
                }),
                out,
            )
        }
        _ => bail!(
            "frame too large: {} bytes > {} limit (unsplittable)",
            body.len(),
            max_frame
        ),
    }
}

/// Zero-re-encode splitter for over-limit [`NetMsg::WindowBatch`] frames:
/// every event is encoded exactly **once**, event-only chunk frames are
/// packed greedily under `max_frame` by slicing those encodings, and one
/// final chunk carries the window's sync flush, space ops and trailing
/// bound (possibly with zero events — a valid batch the receiver already
/// handles).  Replaces the halving splitter's O(n log n) whole-batch
/// re-encode with O(n) work; receiver semantics are unchanged — events
/// arrive in emission order and the promise trails everything it covers.
#[allow(clippy::too_many_arguments)]
fn encode_batch_chunks<P: Wire>(
    codec: WireCodec,
    max_frame: usize,
    context: ContextId,
    from: AgentId,
    events: Vec<Event<P>>,
    sync: Vec<SyncMsg>,
    space: Vec<SpaceMsg>,
    bound: Option<SimTime>,
    out: &mut Vec<Vec<u8>>,
) -> Result<()> {
    // Per-event encodings, produced exactly once.
    let encoded: Vec<Vec<u8>> = events
        .iter()
        .map(|e| match codec {
            WireCodec::Json => event_to_json(e).to_string().into_bytes(),
            WireCodec::Binary => {
                let mut b = Vec::with_capacity(64);
                event_to_bin(&mut b, e);
                b
            }
        })
        .collect();
    // Worst-case per-chunk bytes outside the event encodings: the binary
    // header is msg tag + three <= 10-byte varints + a 3-byte empty
    // trailer; the JSON skeleton plus two u64 ids in decimal tops out
    // near 90.  96 covers both; the event bytes dominate real frames.
    const CHUNK_OVERHEAD: usize = 96;
    if max_frame <= CHUNK_OVERHEAD {
        bail!("frame limit {max_frame} bytes is too small to carry any batch chunk");
    }
    let budget = max_frame - CHUNK_OVERHEAD;
    let mut chunk: Vec<usize> = Vec::new(); // indices into `encoded`
    let mut chunk_bytes = 0usize;
    for (i, enc) in encoded.iter().enumerate() {
        if !chunk.is_empty() && chunk_bytes + 1 + enc.len() > budget {
            out.push(assemble_event_chunk(codec, context, from, &chunk, &encoded)?);
            chunk.clear();
            chunk_bytes = 0;
        }
        if chunk.is_empty() && enc.len() > budget {
            bail!(
                "frame too large: one event encodes to {} bytes > {} limit (unsplittable)",
                enc.len(),
                max_frame
            );
        }
        chunk_bytes += enc.len() + if chunk.is_empty() { 0 } else { 1 };
        chunk.push(i);
    }
    if !chunk.is_empty() {
        out.push(assemble_event_chunk(codec, context, from, &chunk, &encoded)?);
    }
    // The final chunk ships the window's sync flush, replication ops and
    // the single trailing promise — after every event chunk, so the bound
    // still never undercuts an event it covers.
    let tail: NetMsg<P> = NetMsg::WindowBatch {
        context,
        from,
        events: Vec::new(),
        sync,
        space,
        bound,
    };
    let body = encode_msg(codec, &tail);
    if body.len() > max_frame {
        bail!(
            "frame too large: batch sync/space tail encodes to {} bytes > {} limit (unsplittable)",
            body.len(),
            max_frame
        );
    }
    out.push(body);
    Ok(())
}

/// Assemble one event-only `WindowBatch` frame body from pre-encoded
/// events (no sync flush, no space ops, no bound).  The hand-assembled
/// JSON parses to exactly what [`msg_to_json`] would produce for the
/// same chunk — key order is irrelevant to the parser.  A non-UTF-8
/// event encoding under the JSON codec is a codec error, not a panic:
/// it flows back through [`encode_split`]'s error path so the sender's
/// writer survives and the send fails loudly.
fn assemble_event_chunk(
    codec: WireCodec,
    context: ContextId,
    from: AgentId,
    chunk: &[usize],
    encoded: &[Vec<u8>],
) -> Result<Vec<u8>> {
    let events_len: usize = chunk.iter().map(|&i| encoded[i].len()).sum();
    Ok(match codec {
        WireCodec::Binary => {
            let mut b = Vec::with_capacity(events_len + 40);
            b.push(2); // WindowBatch msg tag
            bin::put_u64(&mut b, context.raw());
            bin::put_u64(&mut b, from.raw());
            bin::put_u64(&mut b, chunk.len() as u64);
            for &i in chunk {
                b.extend_from_slice(&encoded[i]);
            }
            bin::put_u64(&mut b, 0); // empty sync flush
            bin::put_u64(&mut b, 0); // no space ops
            b.push(0); // no bound
            b
        }
        WireCodec::Json => {
            let mut s = String::with_capacity(events_len + chunk.len() + 96);
            s.push_str(&format!(
                "{{\"k\":\"batch\",\"ctx\":{},\"from\":{},\"evs\":[",
                context.raw(),
                from.raw()
            ));
            for (n, &i) in chunk.iter().enumerate() {
                if n > 0 {
                    s.push(',');
                }
                s.push_str(
                    std::str::from_utf8(&encoded[i])
                        .map_err(|e| anyhow!("event encoding is not valid JSON text: {e}"))?,
                );
            }
            s.push_str("],\"sync\":[]}");
            s.into_bytes()
        }
    })
}

/// What one [`FrameQueue::push`] observed, for the sender's telemetry
/// counters (the queue itself never touches the endpoint gauges).
struct Pushed {
    /// Frames queued immediately after the push.
    occupancy: u64,
    /// Queue depth in force after the push (may have just grown).
    cap: u64,
    /// The depth the push found the queue full at, if it did.
    full_at: Option<u64>,
    /// Microseconds this push spent blocked waiting for room.
    blocked_us: u64,
}

struct FrameQueueState<P> {
    buf: VecDeque<NetMsg<P>>,
    /// Current bound; fixed policies never move it, adaptive ones double
    /// it (up to `FrameQueue::max_cap`) instead of blocking a saturated
    /// sender, and decay it back toward `FrameQueue::min_cap` once the
    /// pressure subsides.
    cap: usize,
    /// Consecutive pops that found occupancy at a quarter of the depth or
    /// less — the calm streak that triggers a decay step.
    calm: u64,
    closed: bool,
}

/// The bounded per-peer writer queue: senders push (blocking when full at
/// the ceiling), the writer thread pops, and `close` ends the stream
/// after the already-queued frames drain (flush-on-drop semantics).
/// Under an adaptive [`WriterQueue`] policy the bound itself grows from
/// the saturation signal — the occupancy high-water reaching the current
/// depth — doubling toward the ceiling.
struct FrameQueue<P> {
    state: Mutex<FrameQueueState<P>>,
    /// Signalled when room frees up (senders wait here).
    can_push: Condvar,
    /// Signalled when a frame arrives or the queue closes (writer waits).
    can_pop: Condvar,
    /// Depth ceiling (== initial cap for fixed policies).
    max_cap: usize,
    /// Depth floor the decay steps never cross (== the configured start
    /// depth; == ceiling for fixed policies, so they never move).
    min_cap: usize,
    /// Doubling steps taken (adaptive depth telemetry).
    grows: AtomicU64,
    /// Halving steps taken once occupancy subsided (decay telemetry).
    shrinks: AtomicU64,
}

impl<P> FrameQueue<P> {
    /// Consecutive calm pops before one decay (halving) step.  High
    /// enough that a transient dip cannot flap the depth, low enough
    /// that a burst's grown capacity is returned within one drain.
    const CALM_POPS_PER_SHRINK: u64 = 32;

    fn new(spec: WriterQueue) -> Self {
        FrameQueue {
            state: Mutex::new(FrameQueueState {
                buf: VecDeque::new(),
                cap: spec.initial().max(1),
                calm: 0,
                closed: false,
            }),
            can_push: Condvar::new(),
            can_pop: Condvar::new(),
            max_cap: spec.ceiling().max(1),
            min_cap: spec.initial().max(1),
            grows: AtomicU64::new(0),
            shrinks: AtomicU64::new(0),
        }
    }

    /// Enqueue one message; `Err(())` if the queue is closed (writer
    /// gone).  Blocks while full at the ceiling; below the ceiling a full
    /// queue grows instead.
    fn push(&self, msg: NetMsg<P>) -> Result<Pushed, ()> {
        let mut st = self.state.lock().unwrap();
        let mut full_at = None;
        let mut blocked_us = 0u64;
        while st.buf.len() >= st.cap && !st.closed {
            if full_at.is_none() {
                full_at = Some(st.cap as u64);
            }
            if st.cap < self.max_cap {
                st.cap = st.cap.saturating_mul(2).min(self.max_cap);
                st.calm = 0;
                self.grows.fetch_add(1, Ordering::Relaxed);
                break;
            }
            let t0 = Instant::now();
            st = self.can_push.wait(st).unwrap();
            blocked_us += t0.elapsed().as_micros() as u64;
        }
        if st.closed {
            return Err(());
        }
        st.buf.push_back(msg);
        let out = Pushed {
            occupancy: st.buf.len() as u64,
            cap: st.cap as u64,
            full_at,
            blocked_us,
        };
        drop(st);
        self.can_pop.notify_one();
        Ok(out)
    }

    /// Dequeue the next message; `None` once the queue is closed *and*
    /// drained — close flushes, never truncates.  Each pop is also the
    /// decay probe: a long enough streak of low-occupancy pops halves a
    /// grown depth back toward the configured floor, so a burst's extra
    /// capacity is not held forever.
    fn pop(&self) -> Option<NetMsg<P>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(m) = st.buf.pop_front() {
                if st.cap > self.min_cap && st.buf.len() <= st.cap / 4 {
                    st.calm += 1;
                    if st.calm >= Self::CALM_POPS_PER_SHRINK {
                        st.cap = (st.cap / 2).max(self.min_cap);
                        st.calm = 0;
                        self.shrinks.fetch_add(1, Ordering::Relaxed);
                    }
                } else {
                    st.calm = 0;
                }
                drop(st);
                self.can_push.notify_one();
                return Some(m);
            }
            if st.closed {
                return None;
            }
            st = self.can_pop.wait(st).unwrap();
        }
    }

    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.can_pop.notify_all();
        self.can_push.notify_all();
    }

    /// (frames queued, current depth, doubling steps, halving steps) for
    /// telemetry.
    fn snapshot(&self) -> (u64, u64, u64, u64) {
        let st = self.state.lock().unwrap();
        (
            st.buf.len() as u64,
            st.cap as u64,
            self.grows.load(Ordering::Relaxed),
            self.shrinks.load(Ordering::Relaxed),
        )
    }
}

/// One peer's dedicated writer: a bounded message queue feeding a thread
/// that encodes and transmits.
struct PeerWriter<P> {
    queue: Arc<FrameQueue<P>>,
    handle: std::thread::JoinHandle<()>,
}

/// TCP endpoint: one listener for inbound peers; per-connection reader
/// threads funnel decoded frames into a single inbox channel; one writer
/// thread per outbound peer (spawned lazily) owns that peer's socket.
pub struct TcpTransport<P> {
    me: AgentId,
    peers: HashMap<AgentId, SocketAddr>,
    opts: TcpOptions,
    writers: Mutex<HashMap<AgentId, PeerWriter<P>>>,
    inbox: Mutex<Receiver<NetMsg<P>>>,
    inbox_tx: Sender<NetMsg<P>>,
    /// Bytes the writer threads have put on the wire (frames + prefixes
    /// + preambles).
    bytes_sent: Arc<AtomicU64>,
    /// Highest writer-queue occupancy ever observed (frames, capped at
    /// the configured depth).
    queue_highwater: AtomicU64,
    /// Cumulative microseconds senders spent blocked on a full writer
    /// queue (backpressure stalls; telemetry only — never consulted for
    /// protocol decisions).
    send_block_us: AtomicU64,
    /// Oversized inbound frames drained and discarded by the readers.
    frames_skipped: Arc<AtomicU64>,
    /// Fatal faults recorded by writer and reader threads, drained by
    /// [`Transport::take_failures`].
    failures: Arc<Mutex<Vec<TransportFailure>>>,
    _listener: std::thread::JoinHandle<()>,
}

impl<P: Wire + Send + 'static> TcpTransport<P> {
    /// Bind `bind_addr` for `me` and remember the full peer address map
    /// (including self).  Uses default [`TcpOptions`].
    pub fn bind(
        me: AgentId,
        bind_addr: SocketAddr,
        peers: HashMap<AgentId, SocketAddr>,
    ) -> Result<Self> {
        Self::bind_with(me, bind_addr, peers, TcpOptions::default())
    }

    /// [`bind`](Self::bind) with explicit [`TcpOptions`].
    pub fn bind_with(
        me: AgentId,
        bind_addr: SocketAddr,
        peers: HashMap<AgentId, SocketAddr>,
        opts: TcpOptions,
    ) -> Result<Self> {
        let listener =
            TcpListener::bind(bind_addr).with_context(|| format!("bind {bind_addr} for {me}"))?;
        Self::from_listener(me, listener, peers, opts)
    }

    /// Build an endpoint from an already-bound listener.  Lets callers use
    /// OS-assigned ports: bind `127.0.0.1:0` listeners first, collect their
    /// `local_addr()`s into the peer map, then construct every endpoint —
    /// the pattern the cross-transport test suite uses to avoid port
    /// collisions.
    pub fn from_listener(
        me: AgentId,
        listener: TcpListener,
        peers: HashMap<AgentId, SocketAddr>,
        opts: TcpOptions,
    ) -> Result<Self> {
        let (tx, rx) = channel();
        let tx_accept = tx.clone();
        let max_frame = opts.max_frame;
        let frames_skipped = Arc::new(AtomicU64::new(0));
        let failures: Arc<Mutex<Vec<TransportFailure>>> = Arc::new(Mutex::new(Vec::new()));
        let skipped_accept = Arc::clone(&frames_skipped);
        let failures_accept = Arc::clone(&failures);
        let handle = std::thread::Builder::new()
            .name(format!("dsim-tcp-accept-{me}"))
            .spawn(move || {
                for stream in listener.incoming() {
                    let Ok(mut stream) = stream else { break };
                    let tx = tx_accept.clone();
                    let skipped = Arc::clone(&skipped_accept);
                    let failures = Arc::clone(&failures_accept);
                    std::thread::spawn(move || {
                        // Sniff the optional preamble; a bare stream is
                        // JSON text (new json-codec peer or pre-codec
                        // fleet member alike).
                        let (codec, mut pending) = match read_connection_codec(&mut stream) {
                            Ok(Some(x)) => x,
                            // Unusable preamble or EOF before one frame:
                            // only this connection is affected.
                            Ok(None) | Err(_) => return,
                        };
                        loop {
                            let frame = match pending.take() {
                                Some(len) => read_frame_body(&mut stream, len, max_frame),
                                None => read_frame(&mut stream, max_frame),
                            };
                            match frame {
                                Ok(ReadFrame::Skipped { prefix, len }) => {
                                    skipped.fetch_add(1, Ordering::Relaxed);
                                    if skipped_frame_is_fatal(codec, &prefix) {
                                        // The drained frame may have carried
                                        // the window's only trailing promise:
                                        // the conservative receiver would wait
                                        // on it forever.  Poison the
                                        // connection so the run aborts loudly
                                        // instead of deadlocking.
                                        let reason = format!(
                                            "oversized {len}-byte inbound frame carried \
                                             data-plane traffic (events/sync promise lost); \
                                             dropping connection"
                                        );
                                        log::error!("{reason}");
                                        failures
                                            .lock()
                                            .unwrap()
                                            .push(TransportFailure { peer: None, reason });
                                        break;
                                    }
                                    // Control/space frames have their own
                                    // recovery paths; connection still good.
                                    continue;
                                }
                                Ok(ReadFrame::Frame(bytes)) => {
                                    match decode_msg::<P>(codec, &bytes) {
                                        Ok(msg) => {
                                            if tx.send(msg).is_err() {
                                                break;
                                            }
                                        }
                                        Err(e) => {
                                            log::error!("bad {codec} frame: {e:#}");
                                            break;
                                        }
                                    }
                                }
                                Err(_) => break,
                            }
                        }
                    });
                }
            })?;
        Ok(TcpTransport {
            me,
            peers,
            opts,
            writers: Mutex::new(HashMap::new()),
            inbox: Mutex::new(rx),
            inbox_tx: tx,
            bytes_sent: Arc::new(AtomicU64::new(0)),
            queue_highwater: AtomicU64::new(0),
            send_block_us: AtomicU64::new(0),
            frames_skipped,
            failures,
            _listener: handle,
        })
    }

    /// Spawn the writer thread owning the socket to `to`.
    fn spawn_writer(&self, to: AgentId) -> Result<PeerWriter<P>> {
        let addr = *self
            .peers
            .get(&to)
            .ok_or_else(|| anyhow!("unknown peer {to}"))?;
        let queue = Arc::new(FrameQueue::new(self.opts.writer_queue));
        let me = self.me;
        let opts = self.opts;
        let bytes = Arc::clone(&self.bytes_sent);
        let failures = Arc::clone(&self.failures);
        let q = Arc::clone(&queue);
        let handle = std::thread::Builder::new()
            .name(format!("dsim-tcp-writer-{me}-{to}"))
            .spawn(move || writer_loop::<P>(me, to, addr, opts, q, bytes, failures))?;
        Ok(PeerWriter { queue, handle })
    }
}

/// Connect with startup retry (peers race to bind) and send the binary
/// preamble when due; counts preamble bytes.  Retries with exponential
/// backoff — `opts.connect_backoff` doubling per attempt, capped at 1 s —
/// until `opts.connect_timeout` of retry budget is spent, then names the
/// unreachable peer and address in the error.
fn connect_peer(
    to: AgentId,
    addr: SocketAddr,
    opts: &TcpOptions,
    bytes: &AtomicU64,
) -> Result<TcpStream> {
    const BACKOFF_CAP: Duration = Duration::from_secs(1);
    let mut backoff = opts.connect_backoff.max(Duration::from_millis(1));
    let mut spent = Duration::ZERO;
    let mut attempts = 0u32;
    loop {
        match TcpStream::connect(addr) {
            Ok(mut s) => {
                s.set_nodelay(true).ok();
                if opts.codec != WireCodec::Json {
                    // JSON connections stay preamble-less — byte-compatible
                    // with pre-codec receivers (module docs).
                    let preamble = [
                        WIRE_MAGIC[0],
                        WIRE_MAGIC[1],
                        WIRE_MAGIC[2],
                        WIRE_MAGIC[3],
                        WIRE_VERSION,
                        opts.codec.tag(),
                    ];
                    s.write_all(&preamble)?;
                    bytes.fetch_add(preamble.len() as u64, Ordering::Relaxed);
                }
                return Ok(s);
            }
            Err(e) if spent < opts.connect_timeout => {
                attempts += 1;
                let wait = backoff.min(opts.connect_timeout - spent);
                log::debug!(
                    "connect to agent {to} at {addr} refused (attempt {attempts}): {e}; \
                     retrying in {wait:?}"
                );
                std::thread::sleep(wait);
                spent += wait;
                backoff = (backoff * 2).min(BACKOFF_CAP);
            }
            Err(e) => {
                return Err(anyhow!(
                    "agent {to} unreachable at {addr} after {attempts} attempts \
                     over {:?}: {e}",
                    spent
                ));
            }
        }
    }
}

/// The per-peer writer: encodes (and size-splits) each queued message and
/// performs the blocking socket writes, off the agent thread.  `pop`
/// drains everything already queued before observing close, so a dropped
/// transport flushes rather than truncates.  Any frame that cannot be
/// transmitted — a hard connection failure, or an unsplittable over-limit
/// message — ends the writer, which closes its queue *and* records a
/// [`TransportFailure`]: a dead writer turns every subsequent send into a
/// loud error, and the recorded failure lets the agent loop abort the run
/// (reporting to the leader) even if it never sends to that peer again.
#[allow(clippy::too_many_arguments)]
fn writer_loop<P: Wire>(
    me: AgentId,
    to: AgentId,
    addr: SocketAddr,
    opts: TcpOptions,
    queue: Arc<FrameQueue<P>>,
    bytes: Arc<AtomicU64>,
    failures: Arc<Mutex<Vec<TransportFailure>>>,
) {
    let mut fatal: Option<String> = None;
    let mut stream: Option<TcpStream> = None;
    let mut frames: Vec<Vec<u8>> = Vec::new();
    'outer: while let Some(msg) = queue.pop() {
        frames.clear();
        if let Err(e) = encode_split(opts.codec, opts.max_frame, msg, &mut frames) {
            log::error!("{me}: writer to {to} exiting on undeliverable frame: {e:#}");
            fatal = Some(format!("undeliverable frame to {to}: {e:#}"));
            break 'outer;
        }
        for frame in &frames {
            if stream.is_none() {
                match connect_peer(to, addr, &opts, &bytes) {
                    Ok(s) => stream = Some(s),
                    Err(e) => {
                        log::error!("{me}: writer to {to} exiting: {e:#}");
                        fatal = Some(format!("connect to {to} failed: {e:#}"));
                        break 'outer;
                    }
                }
            }
            let first_try = write_frame(stream.as_mut().expect("connected above"), frame);
            if let Err(e) = first_try {
                // One reconnect attempt on a stale socket.
                log::warn!("{me}: resend to {to} after {e}");
                stream = None;
                let retried = connect_peer(to, addr, &opts, &bytes)
                    .and_then(|mut s| write_frame(&mut s, frame).map(|()| s));
                match retried {
                    Ok(s) => stream = Some(s),
                    Err(e) => {
                        log::error!("{me}: writer to {to} exiting: {e:#}");
                        fatal = Some(format!("write to {to} failed twice: {e:#}"));
                        break 'outer;
                    }
                }
            }
            bytes.fetch_add(frame.len() as u64 + 4, Ordering::Relaxed);
        }
    }
    // A failure exit (as opposed to a normal close-initiated drain) is
    // fatal for the whole run: FIFO delivery to `to` can no longer be
    // upheld.  Record it where the agent loop will see it.
    if let Some(reason) = fatal {
        failures.lock().unwrap().push(TransportFailure {
            peer: Some(to),
            reason,
        });
    }
    // Whether close() initiated this exit or a failure did, mark the
    // queue closed so blocked and future senders fail loudly instead of
    // queueing into the void.
    queue.close();
}

impl<P> Drop for TcpTransport<P> {
    /// Flush and join every writer: closing a queue lets its writer drain
    /// the already-queued frames, then exit.
    fn drop(&mut self) {
        let writers = std::mem::take(&mut *self.writers.lock().unwrap());
        for (_, w) in writers {
            w.queue.close();
            let _ = w.handle.join();
        }
    }
}

impl<P: Wire + Clone + Send + 'static> Transport<P> for TcpTransport<P> {
    fn me(&self) -> AgentId {
        self.me
    }

    fn agents(&self) -> Vec<AgentId> {
        let mut v: Vec<AgentId> = self.peers.keys().copied().collect();
        v.sort();
        v
    }

    /// Enqueue on the peer's bounded writer queue.  Blocks when the queue
    /// is full (backpressure — frames are never dropped); errors if the
    /// peer is unknown or its writer has exited on a dead connection.
    fn send(&self, to: AgentId, msg: NetMsg<P>) -> Result<()> {
        if to == self.me {
            // Loopback without a socket.
            self.inbox_tx
                .send(msg)
                .map_err(|_| anyhow!("self inbox closed"))?;
            return Ok(());
        }
        // Clone the queue out of the lock: a backpressure block must not
        // hold the writer map against sends to other peers.
        let queue = {
            let mut writers = self.writers.lock().unwrap();
            if !writers.contains_key(&to) {
                let w = self.spawn_writer(to)?;
                writers.insert(to, w);
            }
            Arc::clone(&writers[&to].queue)
        };
        match queue.push(msg) {
            Ok(p) => {
                // The running occupancy max (capped at the live depth) is
                // the queue-high-water telemetry the adaptive window
                // controller consumes; a push that found the queue full
                // pins the mark at the depth it saturated, and any wait is
                // metered so the controller (and the operator) can see the
                // fleet is wire-bound.  Backpressure, never loss.
                if let Some(full_cap) = p.full_at {
                    self.queue_highwater.fetch_max(full_cap, Ordering::Relaxed);
                }
                self.queue_highwater
                    .fetch_max(p.occupancy.min(p.cap), Ordering::Relaxed);
                if p.blocked_us > 0 {
                    self.send_block_us.fetch_add(p.blocked_us, Ordering::Relaxed);
                }
                Ok(())
            }
            Err(()) => {
                // Writer died (connection failure).  Remove it so a later
                // send gets a fresh writer and thus a fresh connect
                // attempt.
                if let Some(w) = self.writers.lock().unwrap().remove(&to) {
                    let _ = w.handle.join();
                }
                bail!("writer for {to} has shut down (connection failed)")
            }
        }
    }

    fn recv_timeout(&self, timeout: Duration) -> Option<NetMsg<P>> {
        let rx = self.inbox.lock().unwrap();
        if timeout.is_zero() {
            rx.try_recv().ok()
        } else {
            rx.recv_timeout(timeout).ok()
        }
    }

    fn wire_bytes(&self) -> u64 {
        self.bytes_sent.load(Ordering::Relaxed)
    }

    fn telemetry(&self) -> TransportTelemetry {
        // Depth is live per peer under an adaptive policy: report the
        // deepest queue (the initial depth before any writer exists).
        let (occupancy, depth, grows, shrinks) = {
            let writers = self.writers.lock().unwrap();
            let mut occ = 0;
            let mut depth = self.opts.writer_queue.initial() as u64;
            let mut grows = 0;
            let mut shrinks = 0;
            for w in writers.values() {
                let (o, c, g, s) = w.queue.snapshot();
                occ = occ.max(o);
                depth = depth.max(c);
                grows += g;
                shrinks += s;
            }
            (occ, depth, grows, shrinks)
        };
        TransportTelemetry {
            queue_depth: depth,
            queue_occupancy: occupancy,
            queue_highwater: self.queue_highwater.load(Ordering::Relaxed),
            send_block_us: self.send_block_us.load(Ordering::Relaxed),
            queue_grows: grows,
            queue_shrinks: shrinks,
            frames_skipped: self.frames_skipped.load(Ordering::Relaxed),
        }
    }

    fn take_failures(&self) -> Vec<TransportFailure> {
        std::mem::take(&mut *self.failures.lock().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inproc_roundtrip_and_order() {
        let net: InProcNetwork<u32> = InProcNetwork::new();
        let a = net.endpoint(AgentId(1));
        let b = net.endpoint(AgentId(2));
        for i in 0..10u64 {
            a.send(
                AgentId(2),
                NetMsg::Control(ControlMsg::Probe {
                    context: ContextId(i),
                    round: 0,
                }),
            )
            .unwrap();
        }
        for i in 0..10u64 {
            match b.recv_timeout(Duration::from_secs(1)).unwrap() {
                NetMsg::Control(ControlMsg::Probe { context, .. }) => {
                    assert_eq!(context, ContextId(i)); // FIFO preserved
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(net.total_sent(), 10);
    }

    #[test]
    fn inproc_unknown_agent_errors() {
        let net: InProcNetwork<u32> = InProcNetwork::new();
        let a = net.endpoint(AgentId(1));
        assert!(a
            .send(AgentId(9), NetMsg::Control(ControlMsg::Shutdown))
            .is_err());
    }

    #[test]
    fn wire_event_roundtrip() {
        let ev = Event {
            time: SimTime::new(1.5),
            tie: (3, 42),
            src_agent: AgentId(3),
            src_lp: LpId(7),
            dst_lp: LpId(8),
            payload: 99u32,
        };
        let j = event_to_json(&ev);
        let back: Event<u32> = event_from_json(&j).unwrap();
        assert_eq!(back.time, ev.time);
        assert_eq!(back.tie, ev.tie);
        assert_eq!(back.payload, 99);
    }

    #[test]
    fn wire_sync_roundtrip_with_infinities() {
        for m in [
            SyncMsg::LvtRequest {
                need: SimTime::new(2.0),
                lvt: SimTime::NEG_INF,
            },
            SyncMsg::LvtAnnounce { bound: SimTime::INF },
        ] {
            let j = sync_to_json(&m);
            assert_eq!(sync_from_json(&j).unwrap(), m);
        }
    }

    #[test]
    fn wire_control_roundtrip() {
        let msgs = vec![
            ControlMsg::DeployLp {
                context: ContextId(1),
                lp: LpId(5),
                kind: "cpu".into(),
                params: Json::obj(vec![("power", Json::num(2.5))]),
            },
            ControlMsg::RoutingTable {
                context: ContextId(1),
                routes: vec![(LpId(1), AgentId(2)), (LpId(3), AgentId(4))],
            },
            ControlMsg::ProbeReply {
                context: ContextId(2),
                round: 7,
                from: AgentId(1),
                idle: true,
                sent: 10,
                received: 10,
                lvt: SimTime::new(3.5),
                next_event: SimTime::INF,
                windows: 42,
            },
            ControlMsg::GvtUpdate {
                context: ContextId(1),
                gvt: SimTime::new(4.5),
            },
            ControlMsg::WindowReport {
                context: ContextId(3),
                from: AgentId(2),
                windows: 9,
                records: vec![
                    ("job".into(), Json::num(1.0)),
                    ("transfer".into(), Json::obj(vec![("mb", Json::num(2.0))])),
                ],
            },
            ControlMsg::WindowReport {
                context: ContextId(3),
                from: AgentId(2),
                windows: 10,
                records: vec![], // progress-only notification
            },
            ControlMsg::Shutdown,
            ControlMsg::CheckpointStart {
                context: ContextId(1),
                ckpt: 3,
            },
            ControlMsg::CheckpointReply {
                context: ContextId(1),
                ckpt: 3,
                from: AgentId(2),
                sent: 120,
                received: 118,
            },
            ControlMsg::CheckpointPoll {
                context: ContextId(1),
                ckpt: 3,
            },
            ControlMsg::CheckpointCommit {
                context: ContextId(1),
                ckpt: 3,
            },
            ControlMsg::CheckpointDone {
                context: ContextId(1),
                ckpt: 3,
                from: AgentId(2),
                err: String::new(),
            },
            ControlMsg::Rollback {
                context: ContextId(1),
                ckpt: 3,
            },
            ControlMsg::RollbackDone {
                context: ContextId(1),
                ckpt: 3,
                from: AgentId(2),
                err: "no such checkpoint".into(),
            },
            ControlMsg::Telemetry {
                context: ContextId(1),
                from: AgentId(2),
                snap: TelemetrySnapshot {
                    windows: 8,
                    lvt_s: 12.5,
                    budget: 1024,
                    queue_depth: 3,
                    queue_highwater: 9,
                    wire_bytes: 4096,
                    wire_frames: 17,
                    events_queued: 42,
                    cpu_load: 1.5,
                    mem_used: 0.25,
                    rtt_ms: 3.75,
                },
            },
            ControlMsg::TraceChunk {
                context: ContextId(1),
                from: AgentId(2),
                seq: 4,
                dropped: 7,
                spans: vec![
                    crate::trace::TraceSpan {
                        kind: SpanKind::LpDispatch,
                        t_s: 1.5,
                        dur_s: 0.0,
                        lp: 9,
                        aux: 3,
                    },
                    crate::trace::TraceSpan {
                        kind: SpanKind::EventSend,
                        t_s: 2.25,
                        dur_s: 0.0,
                        lp: 9,
                        aux: 11,
                    },
                ],
            },
            ControlMsg::PhaseReport {
                context: ContextId(1),
                from: AgentId(2),
                profile: {
                    let mut p = PhaseProfile::default();
                    p.record(crate::trace::Phase::LpDispatch, 120);
                    p.record(crate::trace::Phase::WriterFlush, 7);
                    p
                },
            },
        ];
        for m in msgs {
            let j = control_to_json(&m);
            assert_eq!(control_from_json(&j).unwrap(), m);
        }
    }

    // ------------------------------------------------------------------
    // Property-style codec coverage (satellite: every NetMsg variant,
    // including WindowBatch and the legacy pre-batch frames, through the
    // full encode -> serialize -> parse -> decode -> re-encode cycle).
    // ------------------------------------------------------------------

    use crate::util::Pcg32;

    fn rand_time(rng: &mut Pcg32) -> SimTime {
        match rng.below(10) {
            0 => SimTime::INF,
            1 => SimTime::NEG_INF,
            _ => SimTime::new(rng.uniform(0.0, 1e6)),
        }
    }

    fn rand_event(rng: &mut Pcg32) -> Event<u32> {
        Event {
            time: SimTime::new(rng.uniform(0.0, 1e6)),
            tie: (rng.below(8), rng.next_u32() as u64),
            src_agent: AgentId(rng.below(8)),
            src_lp: LpId(rng.below(64)),
            dst_lp: LpId(rng.below(64)),
            payload: rng.next_u32(),
        }
    }

    fn rand_sync(rng: &mut Pcg32) -> SyncMsg {
        if rng.chance(0.5) {
            SyncMsg::LvtRequest {
                need: rand_time(rng),
                lvt: rand_time(rng),
            }
        } else {
            SyncMsg::LvtAnnounce { bound: rand_time(rng) }
        }
    }

    fn rand_json(rng: &mut Pcg32) -> Json {
        Json::obj(vec![
            ("x", Json::num(rng.uniform(-10.0, 10.0))),
            ("s", Json::str(format!("v{}", rng.below(100)))),
        ])
    }

    fn rand_control(rng: &mut Pcg32) -> ControlMsg {
        let ctx = ContextId(rng.below(4));
        match rng.below(25) {
            0 => ControlMsg::DeployLp {
                context: ctx,
                lp: LpId(rng.below(64)),
                kind: format!("kind{}", rng.below(4)),
                params: rand_json(rng),
            },
            1 => ControlMsg::RoutingTable {
                context: ctx,
                routes: (0..rng.below(5))
                    .map(|i| (LpId(i), AgentId(rng.below(4))))
                    .collect(),
            },
            2 => ControlMsg::Bootstrap {
                context: ctx,
                time: rand_time(rng),
                dst: LpId(rng.below(64)),
                payload: rand_json(rng),
            },
            3 => ControlMsg::StartRun {
                context: ctx,
                participants: (1..=rng.below(5) + 1).map(AgentId).collect(),
            },
            4 => ControlMsg::Probe {
                context: ctx,
                round: rng.below(100),
            },
            5 => ControlMsg::ProbeReply {
                context: ctx,
                round: rng.below(100),
                from: AgentId(rng.below(8)),
                idle: rng.chance(0.5),
                sent: rng.below(1000),
                received: rng.below(1000),
                lvt: rand_time(rng),
                next_event: rand_time(rng),
                windows: rng.below(1000),
            },
            6 => ControlMsg::GvtUpdate {
                context: ctx,
                gvt: rand_time(rng),
            },
            7 => ControlMsg::EndRun { context: ctx },
            8 => ControlMsg::FinalStats {
                context: ctx,
                from: AgentId(rng.below(8)),
                stats: HostStatsView {
                    events_processed: rng.below(100_000),
                    events_sent_remote: rng.below(10_000),
                    null_messages_sent: rng.below(1000),
                    windows: rng.below(1000),
                    wire_frames: rng.below(1000),
                    wire_bytes: rng.below(1 << 20),
                    budget_last: rng.below(1 << 16),
                    queue_highwater: rng.below(256),
                    queue_grows: rng.below(8),
                    queue_shrinks: rng.below(8),
                    events_rejected: rng.below(4),
                    lvt_s: rng.uniform(0.0, 1e5),
                    ..HostStatsView::default()
                },
            },
            9 => ControlMsg::Result {
                context: ctx,
                kind: format!("kind{}", rng.below(4)),
                record: rand_json(rng),
            },
            10 => ControlMsg::WindowReport {
                context: ctx,
                from: AgentId(rng.below(8)),
                windows: rng.below(10_000),
                records: (0..rng.below(4))
                    .map(|_| (format!("k{}", rng.below(3)), rand_json(rng)))
                    .collect(),
            },
            11 => ControlMsg::PerfSample {
                from: AgentId(rng.below(8)),
                value: rng.uniform(0.0, 10.0),
                load: rand_json(rng),
            },
            12 => ControlMsg::Heartbeat {
                from: AgentId(rng.below(8)),
                seq: rng.below(100_000),
            },
            13 => ControlMsg::AgentFailed {
                from: AgentId(rng.below(8)),
                reason: format!("reason{}", rng.below(4)),
            },
            14 => ControlMsg::CheckpointStart {
                context: ctx,
                ckpt: rng.below(16),
            },
            15 => ControlMsg::CheckpointReply {
                context: ctx,
                ckpt: rng.below(16),
                from: AgentId(rng.below(8)),
                sent: rng.below(10_000),
                received: rng.below(10_000),
            },
            16 => ControlMsg::CheckpointPoll {
                context: ctx,
                ckpt: rng.below(16),
            },
            17 => ControlMsg::CheckpointCommit {
                context: ctx,
                ckpt: rng.below(16),
            },
            18 => ControlMsg::CheckpointDone {
                context: ctx,
                ckpt: rng.below(16),
                from: AgentId(rng.below(8)),
                err: if rng.chance(0.5) {
                    String::new()
                } else {
                    format!("err{}", rng.below(4))
                },
            },
            19 => ControlMsg::Rollback {
                context: ctx,
                ckpt: rng.below(16),
            },
            20 => ControlMsg::RollbackDone {
                context: ctx,
                ckpt: rng.below(16),
                from: AgentId(rng.below(8)),
                err: if rng.chance(0.5) {
                    String::new()
                } else {
                    format!("err{}", rng.below(4))
                },
            },
            21 => ControlMsg::Telemetry {
                context: ctx,
                from: AgentId(rng.below(8)),
                snap: TelemetrySnapshot {
                    windows: rng.below(10_000),
                    lvt_s: rng.uniform(0.0, 1e5),
                    budget: rng.below(1 << 16),
                    queue_depth: rng.below(256),
                    queue_highwater: rng.below(256),
                    wire_bytes: rng.below(1 << 20),
                    wire_frames: rng.below(10_000),
                    events_queued: rng.below(100_000),
                    cpu_load: rng.uniform(0.0, 64.0),
                    mem_used: rng.uniform(0.0, 1.0),
                    rtt_ms: rng.uniform(0.0, 100.0),
                },
            },
            22 => ControlMsg::TraceChunk {
                context: ctx,
                from: AgentId(rng.below(8)),
                seq: rng.below(16),
                dropped: rng.below(1000),
                spans: (0..rng.below(6))
                    .map(|_| crate::trace::TraceSpan {
                        kind: crate::trace::SpanKind::from_u8(rng.below(5) as u8).unwrap(),
                        t_s: rng.uniform(0.0, 1e5),
                        dur_s: rng.uniform(0.0, 10.0),
                        lp: rng.below(64),
                        aux: rng.below(1000),
                    })
                    .collect(),
            },
            23 => ControlMsg::PhaseReport {
                context: ctx,
                from: AgentId(rng.below(8)),
                profile: {
                    let mut p = PhaseProfile::default();
                    for _ in 0..rng.below(20) {
                        let phase = match rng.below(5) {
                            0 => crate::trace::Phase::QueuePop,
                            1 => crate::trace::Phase::LpDispatch,
                            2 => crate::trace::Phase::BatchEncode,
                            3 => crate::trace::Phase::WriterFlush,
                            _ => crate::trace::Phase::LeaderRecv,
                        };
                        p.record(phase, rng.below(1 << 20));
                    }
                    p
                },
            },
            _ => ControlMsg::Shutdown,
        }
    }

    fn rand_msg(rng: &mut Pcg32) -> NetMsg<u32> {
        let ctx = ContextId(rng.below(4));
        match rng.below(5) {
            0 => NetMsg::Event {
                context: ctx,
                event: rand_event(rng),
                bound: rand_time(rng),
            },
            1 => NetMsg::WindowBatch {
                context: ctx,
                from: AgentId(rng.below(8)),
                events: (0..rng.below(6)).map(|_| rand_event(rng)).collect(),
                sync: (0..rng.below(4)).map(|_| rand_sync(rng)).collect(),
                space: (0..rng.below(3)).map(|_| rand_space(rng)).collect(),
                bound: if rng.chance(0.7) {
                    Some(rand_time(rng))
                } else {
                    None // non-final split chunk
                },
            },
            2 => NetMsg::Sync {
                context: ctx,
                from: AgentId(rng.below(8)),
                msg: rand_sync(rng),
            },
            3 => NetMsg::Space(rand_space(rng)),
            _ => NetMsg::Control(rand_control(rng)),
        }
    }

    fn rand_space(rng: &mut Pcg32) -> SpaceMsg {
        if rng.chance(0.5) {
            SpaceMsg::Write(crate::space::Entry {
                key: format!("cpu/{}", rng.below(10)),
                fields: rand_json(rng),
                version: rng.below(100),
                writer: AgentId(rng.below(8)),
            })
        } else {
            SpaceMsg::Remove {
                key: format!("key{}", rng.below(10)),
                version: rng.below(100),
            }
        }
    }

    #[test]
    fn wire_roundtrip_property_every_variant() {
        crate::testkit::check("netmsg wire roundtrip", 300, |rng| {
            let msg = rand_msg(rng);
            // The full wire cycle: encode, serialize, parse, decode,
            // re-encode.  Byte-identical re-encoding implies the decode
            // lost nothing (serialization is deterministic).
            let text = msg_to_json(&msg).to_string();
            let parsed = Json::parse(&text).map_err(|e| format!("parse: {e}"))?;
            let back: NetMsg<u32> =
                msg_from_json(&parsed).map_err(|e| format!("decode {text}: {e:#}"))?;
            let text2 = msg_to_json(&back).to_string();
            if text == text2 {
                Ok(())
            } else {
                Err(format!("re-encode mismatch:\n  {text}\n  {text2}"))
            }
        });
    }

    #[test]
    fn binary_roundtrip_property_every_variant() {
        crate::testkit::check("netmsg binary roundtrip", 300, |rng| {
            let msg = rand_msg(rng);
            let body = encode_msg(WireCodec::Binary, &msg);
            let back: NetMsg<u32> = decode_msg(WireCodec::Binary, &body)
                .map_err(|e| format!("decode: {e:#}"))?;
            // Byte-identical re-encoding implies the decode lost nothing
            // (the encoding is deterministic).
            let body2 = encode_msg(WireCodec::Binary, &back);
            if body != body2 {
                return Err(format!("re-encode mismatch for {msg:?}"));
            }
            // Cross-codec agreement: the binary cycle and the JSON cycle
            // must describe the same message.
            let via_json = msg_to_json(&back).to_string();
            let direct_json = msg_to_json(&msg).to_string();
            if via_json != direct_json {
                return Err(format!(
                    "codec divergence:\n  {direct_json}\n  {via_json}"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn binary_codec_is_smaller_than_json() {
        // The codec exists to shrink the hot path; a representative batch
        // frame must be several times smaller in binary.
        let mut rng = Pcg32::seeded(7);
        let msg: NetMsg<u32> = NetMsg::WindowBatch {
            context: ContextId(1),
            from: AgentId(1),
            events: (0..32).map(|_| rand_event(&mut rng)).collect(),
            sync: vec![SyncMsg::LvtAnnounce { bound: SimTime::new(1234.567890123) }],
            space: vec![],
            bound: Some(SimTime::new(1234.567890123)),
        };
        let json = encode_msg(WireCodec::Json, &msg).len();
        let binary = encode_msg(WireCodec::Binary, &msg).len();
        assert!(
            binary * 3 <= json,
            "binary {binary}B vs json {json}B: expected >= 3x reduction"
        );
    }

    #[test]
    fn binary_decode_rejects_corrupt_bodies() {
        let msg: NetMsg<u32> = NetMsg::Control(ControlMsg::Probe {
            context: ContextId(1),
            round: 9,
        });
        let body = encode_msg(WireCodec::Binary, &msg);
        // Truncations at every prefix length fail cleanly (never panic).
        for cut in 0..body.len() {
            assert!(
                decode_msg::<u32>(WireCodec::Binary, &body[..cut]).is_err(),
                "truncation at {cut} decoded"
            );
        }
        // Trailing garbage is rejected, not ignored.
        let mut long = body.clone();
        long.push(0);
        assert!(decode_msg::<u32>(WireCodec::Binary, &long).is_err());
        // Unknown tags.
        assert!(decode_msg::<u32>(WireCodec::Binary, &[0xee]).is_err());
        // A huge vec count with no bytes behind it: rejected pre-alloc.
        let mut evil = vec![2u8]; // WindowBatch
        bin::put_u64(&mut evil, 1); // ctx
        bin::put_u64(&mut evil, 1); // from
        bin::put_u64(&mut evil, u32::MAX as u64); // "events"
        assert!(decode_msg::<u32>(WireCodec::Binary, &evil).is_err());
    }

    #[test]
    fn legacy_pre_batch_frames_still_decode() {
        // Exact pre-batch wire frames (one frame per message): the new
        // codec must accept them verbatim so mixed fleets interoperate.
        let event = r#"{"k":"event","ctx":1,"ev":{"t":9,"tie0":1,"tie1":1,"sa":1,"sl":1,"dl":2,"p":7},"b":9}"#;
        match msg_from_json::<u32>(&Json::parse(event).unwrap()).unwrap() {
            NetMsg::Event { event, bound, .. } => {
                assert_eq!(event.payload, 7);
                assert_eq!(bound, SimTime::new(9.0));
            }
            other => panic!("unexpected {other:?}"),
        }
        let sync = r#"{"k":"sync","ctx":1,"from":2,"msg":{"k":"ann","bound":"inf"}}"#;
        match msg_from_json::<u32>(&Json::parse(sync).unwrap()).unwrap() {
            NetMsg::Sync {
                msg: SyncMsg::LvtAnnounce { bound },
                from,
                ..
            } => {
                assert_eq!(bound, SimTime::INF);
                assert_eq!(from, AgentId(2));
            }
            other => panic!("unexpected {other:?}"),
        }
        // Pre-window ProbeReply without the "win" field defaults to 0.
        let reply = r#"{"k":"control","c":{"k":"probe-reply","ctx":1,"round":3,"from":2,"idle":true,"sent":4,"received":4,"lvt":1.5,"next":"inf"}}"#;
        match msg_from_json::<u32>(&Json::parse(reply).unwrap()).unwrap() {
            NetMsg::Control(ControlMsg::ProbeReply { windows, round, .. }) => {
                assert_eq!(windows, 0);
                assert_eq!(round, 3);
            }
            other => panic!("unexpected {other:?}"),
        }
        // A batch frame without "b" (non-final split chunk) and without
        // "sp" (pre-space fleet): bound = None, no replication ops.
        let chunk = r#"{"k":"batch","ctx":1,"from":2,"evs":[],"sync":[]}"#;
        match msg_from_json::<u32>(&Json::parse(chunk).unwrap()).unwrap() {
            NetMsg::WindowBatch { bound, events, space, .. } => {
                assert!(bound.is_none());
                assert!(events.is_empty());
                assert!(space.is_empty());
            }
            other => panic!("unexpected {other:?}"),
        }
        // Garbage frames are rejected, not panicked on.
        assert!(msg_from_json::<u32>(&Json::parse(r#"{"k":"bogus"}"#).unwrap()).is_err());
    }

    // ------------------------------------------------------------------
    // Frame-size limit: oversized frames fail cleanly on both sides.
    // ------------------------------------------------------------------

    #[test]
    fn read_frame_skips_oversized_and_recovers() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (mut server, _) = listener.accept().unwrap();
        write_frame(&mut client, &[b'x'; 100]).unwrap();
        write_frame(&mut client, b"ok").unwrap();
        // The 100-byte frame exceeds the limit: skipped (drained, with its
        // head retained for classification), and the next frame on the
        // same stream still reads correctly.
        match read_frame(&mut server, 16).unwrap() {
            ReadFrame::Skipped { prefix, len } => {
                assert_eq!(len, 100);
                assert_eq!(prefix, vec![b'x'; SKIP_PREFIX]);
            }
            ReadFrame::Frame(_) => panic!("oversized frame not skipped"),
        }
        match read_frame(&mut server, 16).unwrap() {
            ReadFrame::Frame(bytes) => assert_eq!(bytes, b"ok"),
            ReadFrame::Skipped { .. } => panic!("valid frame skipped"),
        }
    }

    #[test]
    fn skipped_frame_classification() {
        // Binary msg tags: Space (4) and Control (5) survive; data-plane
        // tags and garbage are fatal.
        assert!(!skipped_frame_is_fatal(WireCodec::Binary, &[4]));
        assert!(!skipped_frame_is_fatal(WireCodec::Binary, &[5]));
        assert!(skipped_frame_is_fatal(WireCodec::Binary, &[2]));
        assert!(skipped_frame_is_fatal(WireCodec::Binary, &[]));
        // JSON prefixes follow sorted-key serialization: Control leads
        // with its "c" payload, Space with `"k":"space"` (k < op); the
        // sorted batch (`{"ctx":`), the hand-assembled batch chunk
        // (`{"k":"batch"`), and garbage are all fatal.
        let ctl = NetMsg::<u32>::Control(ControlMsg::Heartbeat { from: AgentId(3), seq: 7 });
        let ctl_text = msg_to_json(&ctl).to_string();
        assert!(ctl_text.starts_with("{\"c\":"), "got {ctl_text}");
        assert!(!skipped_frame_is_fatal(WireCodec::Json, &ctl_text.as_bytes()[..SKIP_PREFIX]));
        assert!(!skipped_frame_is_fatal(WireCodec::Json, b"{\"k\":\"space\",\"op\":"));
        assert!(skipped_frame_is_fatal(WireCodec::Json, b"{\"ctx\":4,\"evs\":["));
        assert!(skipped_frame_is_fatal(WireCodec::Json, b"{\"k\":\"batch\",\"ctx\""));
        assert!(skipped_frame_is_fatal(WireCodec::Json, b"xxxxxxxx"));
    }

    #[test]
    fn oversized_control_frame_does_not_poison_reader() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peers: HashMap<AgentId, SocketAddr> = [(AgentId(1), addr)].into_iter().collect();
        let opts = TcpOptions {
            max_frame: 1024,
            ..TcpOptions::default()
        };
        let t: TcpTransport<u32> =
            TcpTransport::from_listener(AgentId(1), listener, peers, opts).unwrap();
        // A peer with a larger frame limit writes an oversized *control*
        // frame, then a valid one, on the same connection: the control
        // plane has its own recovery, so the reader survives, counts the
        // skip, and delivers the valid message.
        let mut rogue = TcpStream::connect(addr).unwrap();
        let big: NetMsg<u32> = NetMsg::Control(ControlMsg::Result {
            context: ContextId(1),
            kind: "x".repeat(4096),
            record: Json::Null,
        });
        write_frame(&mut rogue, msg_to_json(&big).to_string().as_bytes()).unwrap();
        let valid: NetMsg<u32> = NetMsg::Control(ControlMsg::Shutdown);
        write_frame(&mut rogue, msg_to_json(&valid).to_string().as_bytes()).unwrap();
        assert!(matches!(
            t.recv_timeout(Duration::from_secs(5)).unwrap(),
            NetMsg::Control(ControlMsg::Shutdown)
        ));
        assert_eq!(t.telemetry().frames_skipped, 1);
        assert!(t.take_failures().is_empty(), "control skip is not fatal");
    }

    #[test]
    fn oversized_data_frame_poisons_connection() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peers: HashMap<AgentId, SocketAddr> = [(AgentId(1), addr)].into_iter().collect();
        let opts = TcpOptions {
            max_frame: 1024,
            ..TcpOptions::default()
        };
        let t: TcpTransport<u32> =
            TcpTransport::from_listener(AgentId(1), listener, peers, opts).unwrap();
        // An oversized WindowBatch may have carried the window's only
        // trailing promise: the connection is poisoned (a later frame on
        // it is NOT delivered) and the failure is recorded for the agent
        // loop to abort on, instead of the old silent skip-and-deadlock.
        let mut rogue = TcpStream::connect(addr).unwrap();
        let big: NetMsg<u32> = NetMsg::WindowBatch {
            context: ContextId(1),
            from: AgentId(2),
            events: Vec::new(),
            sync: vec![SyncMsg::LvtAnnounce { bound: SimTime::new(9.0) }],
            space: vec![SpaceMsg::Remove {
                key: "k".repeat(4096),
                version: 1,
            }],
            bound: Some(SimTime::new(9.0)),
        };
        write_frame(&mut rogue, msg_to_json(&big).to_string().as_bytes()).unwrap();
        let valid: NetMsg<u32> = NetMsg::Control(ControlMsg::Shutdown);
        write_frame(&mut rogue, msg_to_json(&valid).to_string().as_bytes()).unwrap();
        assert!(
            t.recv_timeout(Duration::from_millis(500)).is_none(),
            "poisoned connection must not deliver later frames"
        );
        assert_eq!(t.telemetry().frames_skipped, 1);
        let failures = t.take_failures();
        assert_eq!(failures.len(), 1, "data-plane skip must be recorded as fatal");
        assert!(failures[0].reason.contains("data-plane"));
    }

    #[test]
    fn oversized_window_batch_splits_and_reassembles() {
        // Two endpoints with a tiny frame limit: a large batch must arrive
        // complete, in order, as several chunks, with the sync flush and
        // the promise riding only the final chunk.
        let (l1, l2) = (
            TcpListener::bind("127.0.0.1:0").unwrap(),
            TcpListener::bind("127.0.0.1:0").unwrap(),
        );
        let peers: HashMap<AgentId, SocketAddr> = [
            (AgentId(1), l1.local_addr().unwrap()),
            (AgentId(2), l2.local_addr().unwrap()),
        ]
        .into_iter()
        .collect();
        // JSON codec: the split logic is codec-independent, and JSON's
        // frame sizes make a 256-byte limit force a multi-way split.
        let opts = TcpOptions {
            max_frame: 256,
            codec: WireCodec::Json,
            ..TcpOptions::default()
        };
        let t1: TcpTransport<u32> =
            TcpTransport::from_listener(AgentId(1), l1, peers.clone(), opts).unwrap();
        let t2: TcpTransport<u32> =
            TcpTransport::from_listener(AgentId(2), l2, peers, opts).unwrap();
        let events: Vec<Event<u32>> = (0..8u64)
            .map(|i| Event {
                time: SimTime::new(i as f64),
                tie: (1, i),
                src_agent: AgentId(1),
                src_lp: LpId(1),
                dst_lp: LpId(2),
                payload: i as u32,
            })
            .collect();
        t1.send(
            AgentId(2),
            NetMsg::WindowBatch {
                context: ContextId(1),
                from: AgentId(1),
                events,
                sync: vec![SyncMsg::LvtAnnounce { bound: SimTime::new(99.0) }],
                space: vec![SpaceMsg::Remove { key: "k".into(), version: 1 }],
                bound: Some(SimTime::new(99.0)),
            },
        )
        .unwrap();
        let mut got = Vec::new();
        let mut bounds = Vec::new();
        let mut syncs = 0;
        let mut spaces = 0;
        // The final chunk is the one carrying the bound; events precede it.
        loop {
            match t2.recv_timeout(Duration::from_secs(5)).expect("batch chunk") {
                NetMsg::WindowBatch { events, sync, space, bound, .. } => {
                    got.extend(events.into_iter().map(|e| e.payload));
                    syncs += sync.len();
                    spaces += space.len();
                    let done = bound.is_some();
                    bounds.push(bound);
                    if done {
                        break;
                    }
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(got, (0..8u32).collect::<Vec<_>>());
        assert!(bounds.len() > 1, "batch should have split");
        assert!(bounds.last().unwrap().is_some(), "final chunk carries the bound");
        assert!(bounds[..bounds.len() - 1].iter().all(Option::is_none));
        assert_eq!(syncs, 1, "sync flush rides the final chunk only");
        assert_eq!(spaces, 1, "space ops ride the final chunk only");
    }

    #[test]
    fn unsplittable_oversized_frame_fails_loudly() {
        let opts = TcpOptions {
            max_frame: 64,
            ..TcpOptions::default()
        };
        let (t1, t2) = tcp_pair(opts, opts);
        // A control frame cannot be split; over the limit it kills the
        // peer's writer (the receiver would drain and discard it anyway),
        // so a subsequent send errors instead of the run silently missing
        // a control-plane frame.  The death is asynchronous — poll.
        let big = ControlMsg::Result {
            context: ContextId(1),
            kind: "x".repeat(128),
            record: Json::Null,
        };
        t1.send(AgentId(2), NetMsg::Control(big)).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            match t1.send(AgentId(2), NetMsg::Control(ControlMsg::Shutdown)) {
                Err(_) => break, // writer observed dead: loud failure
                Ok(()) => assert!(
                    std::time::Instant::now() < deadline,
                    "sends kept succeeding after an undeliverable frame"
                ),
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        // The receiver never saw the oversized frame.
        assert!(!matches!(
            t2.recv_timeout(Duration::from_millis(200)),
            Some(NetMsg::Control(ControlMsg::Result { .. }))
        ));
    }

    #[test]
    fn writer_death_is_recorded_as_transport_failure() {
        // A writer that dies (here: on an undeliverable frame) must leave
        // a TransportFailure behind for the agent loop to abort on — not
        // just close its queue into the void.  The death is asynchronous:
        // poll.
        let opts = TcpOptions {
            max_frame: 64,
            ..TcpOptions::default()
        };
        let (t1, _t2) = tcp_pair(opts, opts);
        let big = ControlMsg::Result {
            context: ContextId(1),
            kind: "x".repeat(128),
            record: Json::Null,
        };
        t1.send(AgentId(2), NetMsg::Control(big)).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        let failures = loop {
            let f = t1.take_failures();
            if !f.is_empty() {
                break f;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "writer death never surfaced via take_failures"
            );
            std::thread::sleep(Duration::from_millis(10));
        };
        assert_eq!(failures[0].peer, Some(AgentId(2)));
        assert!(failures[0].reason.contains("undeliverable"));
        // Drained: a second take returns nothing new.
        assert!(t1.take_failures().is_empty());
    }

    #[test]
    fn non_utf8_event_encoding_is_a_codec_error_not_a_panic() {
        // A malformed pre-encoded event under the JSON codec flows back
        // through the encode_split error path instead of panicking the
        // writer thread.
        let bad = vec![vec![0xff, 0xfe, 0xfd]];
        let err = assemble_event_chunk(WireCodec::Json, ContextId(1), AgentId(1), &[0], &bad)
            .expect_err("invalid utf8 must be an error");
        assert!(err.to_string().contains("not valid JSON text"));
        // The binary codec is byte-oriented: the same input is fine.
        assert!(
            assemble_event_chunk(WireCodec::Binary, ContextId(1), AgentId(1), &[0], &bad).is_ok()
        );
    }

    /// Two connected endpoints on OS-assigned ports.
    fn tcp_pair(
        o1: TcpOptions,
        o2: TcpOptions,
    ) -> (TcpTransport<u32>, TcpTransport<u32>) {
        let (l1, l2) = (
            TcpListener::bind("127.0.0.1:0").unwrap(),
            TcpListener::bind("127.0.0.1:0").unwrap(),
        );
        let peers: HashMap<AgentId, SocketAddr> = [
            (AgentId(1), l1.local_addr().unwrap()),
            (AgentId(2), l2.local_addr().unwrap()),
        ]
        .into_iter()
        .collect();
        (
            TcpTransport::from_listener(AgentId(1), l1, peers.clone(), o1).unwrap(),
            TcpTransport::from_listener(AgentId(2), l2, peers, o2).unwrap(),
        )
    }

    #[test]
    fn mixed_codec_fleet_interoperates() {
        // Agent 1 speaks binary (preamble), agent 2 speaks JSON (bare
        // stream): each decodes the other per its connection.
        let o_bin = TcpOptions { codec: WireCodec::Binary, ..TcpOptions::default() };
        let o_json = TcpOptions { codec: WireCodec::Json, ..TcpOptions::default() };
        let (t1, t2) = tcp_pair(o_bin, o_json);
        t1.send(
            AgentId(2),
            NetMsg::Control(ControlMsg::Probe { context: ContextId(7), round: 3 }),
        )
        .unwrap();
        match t2.recv_timeout(Duration::from_secs(5)).unwrap() {
            NetMsg::Control(ControlMsg::Probe { context, round }) => {
                assert_eq!((context, round), (ContextId(7), 3));
            }
            other => panic!("unexpected {other:?}"),
        }
        t2.send(
            AgentId(1),
            NetMsg::Control(ControlMsg::Probe { context: ContextId(8), round: 4 }),
        )
        .unwrap();
        match t1.recv_timeout(Duration::from_secs(5)).unwrap() {
            NetMsg::Control(ControlMsg::Probe { context, round }) => {
                assert_eq!((context, round), (ContextId(8), 4));
            }
            other => panic!("unexpected {other:?}"),
        }
        // Binary bytes were actually metered on the wire.
        assert!(t1.wire_bytes() > 0);
    }

    #[test]
    fn bad_preamble_or_truncated_frame_only_kills_its_connection() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let peers: HashMap<AgentId, SocketAddr> = [(AgentId(1), addr)].into_iter().collect();
        let t: TcpTransport<u32> =
            TcpTransport::from_listener(AgentId(1), listener, peers, TcpOptions::default())
                .unwrap();

        // Rogue connection 1: valid magic, unknown codec tag.
        let mut rogue = TcpStream::connect(addr).unwrap();
        rogue.write_all(b"DSIM\x01\x7f").unwrap();
        drop(rogue);
        // Rogue connection 2: wrong version.
        let mut rogue = TcpStream::connect(addr).unwrap();
        rogue.write_all(b"DSIM\x63\x01").unwrap();
        drop(rogue);
        // Rogue connection 3: truncated frame (length prefix promises 100
        // bytes, stream ends after 3).
        let mut rogue = TcpStream::connect(addr).unwrap();
        rogue.write_all(&100u32.to_be_bytes()).unwrap();
        rogue.write_all(&[1, 2, 3]).unwrap();
        drop(rogue);
        // Rogue connection 4: garbage binary body behind a valid preamble.
        let mut rogue = TcpStream::connect(addr).unwrap();
        rogue.write_all(b"DSIM\x01\x01").unwrap();
        write_frame(&mut rogue, &[0xee, 0xff]).unwrap();
        drop(rogue);

        // A well-formed connection afterwards still delivers.
        let mut good = TcpStream::connect(addr).unwrap();
        good.write_all(b"DSIM\x01\x01").unwrap();
        let valid: NetMsg<u32> = NetMsg::Control(ControlMsg::Shutdown);
        write_frame(&mut good, &encode_msg(WireCodec::Binary, &valid)).unwrap();
        assert!(matches!(
            t.recv_timeout(Duration::from_secs(5)).unwrap(),
            NetMsg::Control(ControlMsg::Shutdown)
        ));
    }

    #[test]
    fn writer_queue_flushes_on_drop_and_preserves_fifo() {
        // A tiny queue forces backpressure while the messages flow, and
        // dropping the sender transport must flush everything queued.
        let opts = TcpOptions {
            writer_queue: WriterQueue::Fixed(1),
            ..TcpOptions::default()
        };
        let (t1, t2) = tcp_pair(opts, opts);
        const N: u64 = 200;
        for i in 0..N {
            t1.send(
                AgentId(2),
                NetMsg::Control(ControlMsg::Probe { context: ContextId(i), round: i }),
            )
            .unwrap();
        }
        drop(t1); // joins the writer after it drains the queue
        for i in 0..N {
            match t2.recv_timeout(Duration::from_secs(5)).expect("flushed frame") {
                NetMsg::Control(ControlMsg::Probe { context, .. }) => {
                    assert_eq!(context, ContextId(i), "FIFO violated");
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn writer_queue_telemetry_reports_depth_and_highwater() {
        let opts = TcpOptions {
            writer_queue: WriterQueue::Fixed(4),
            ..TcpOptions::default()
        };
        let (t1, t2) = tcp_pair(opts, opts);
        // Before any send: depth is configured, gauges are zero.
        let t = t1.telemetry();
        assert_eq!(t.queue_depth, 4);
        assert_eq!((t.queue_occupancy, t.queue_highwater, t.send_block_us), (0, 0, 0));
        // Every enqueue raises the high-water mark synchronously (the
        // writer may drain the queue at any speed, so only the mark — not
        // the live occupancy — is deterministic here).
        for i in 0..8u64 {
            t1.send(
                AgentId(2),
                NetMsg::Control(ControlMsg::Probe { context: ContextId(i), round: i }),
            )
            .unwrap();
        }
        let t = t1.telemetry();
        assert!(t.queue_highwater >= 1, "no high-water recorded");
        assert!(t.queue_highwater <= 4, "high-water exceeded depth: {}", t.queue_highwater);
        for _ in 0..8 {
            assert!(t2.recv_timeout(Duration::from_secs(5)).is_some());
        }
        // Loopback sends bypass the writer queues entirely.
        let before = t2.telemetry();
        t2.send(AgentId(2), NetMsg::Control(ControlMsg::Shutdown)).unwrap();
        assert_eq!(t2.telemetry(), before);
        // The in-proc fabric has no queues: permanently all-zero.
        let net: InProcNetwork<u32> = InProcNetwork::new();
        let a = net.endpoint(AgentId(1));
        assert_eq!(a.telemetry(), TransportTelemetry::default());
    }

    #[test]
    fn writer_queue_mode_parse_and_display() {
        assert_eq!("adaptive".parse::<WriterQueue>().unwrap(), WriterQueue::adaptive());
        assert_eq!("fixed(8)".parse::<WriterQueue>().unwrap(), WriterQueue::Fixed(8));
        assert_eq!("256".parse::<WriterQueue>().unwrap(), WriterQueue::Fixed(256));
        assert_eq!(WriterQueue::Fixed(8).to_string(), "fixed(8)");
        assert_eq!(WriterQueue::adaptive().to_string(), "adaptive");
        for bad in ["fixed(0)", "0", "auto", "fixed()", ""] {
            assert!(bad.parse::<WriterQueue>().is_err(), "accepted '{bad}'");
        }
        assert!(WriterQueue::Fixed(0).validate().is_err());
        assert!(WriterQueue::Adaptive { start: 0, max: 4 }.validate().is_err());
        assert!(WriterQueue::Adaptive { start: 8, max: 4 }.validate().is_err());
        assert!(WriterQueue::Adaptive { start: 4, max: 4 }.validate().is_ok());
    }

    #[test]
    fn adaptive_frame_queue_grows_instead_of_blocking() {
        // With no consumer at all, an adaptive queue must absorb pushes
        // beyond its start depth by doubling toward the ceiling — the
        // saturation signal (occupancy high-water == depth) is the grow
        // trigger, deterministic with a single pusher.
        let q: FrameQueue<u32> =
            FrameQueue::new(WriterQueue::Adaptive { start: 1, max: 4 });
        for i in 0..4u64 {
            let p = q
                .push(NetMsg::Control(ControlMsg::Probe { context: ContextId(i), round: i }))
                .expect("queue open");
            assert_eq!(p.blocked_us, 0, "grew instead of blocking");
        }
        let (occ, cap, grows, shrinks) = q.snapshot();
        assert_eq!(occ, 4);
        assert_eq!(cap, 4, "1 -> 2 -> 4");
        assert_eq!(grows, 2);
        assert_eq!(shrinks, 0, "nothing drained yet");
        // FIFO drain, then close -> pop None, push Err.
        for i in 0..4u64 {
            match q.pop().unwrap() {
                NetMsg::Control(ControlMsg::Probe { context, .. }) => {
                    assert_eq!(context, ContextId(i));
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        q.close();
        assert!(q.pop().is_none());
        assert!(q.push(NetMsg::Control(ControlMsg::Shutdown)).is_err());
    }

    #[test]
    fn adaptive_frame_queue_decays_after_drain() {
        // Grow a depth-1 queue to its ceiling of 4, then run a long calm
        // push/pop alternation: every pop observes occupancy <= cap/4, so
        // the decay streak halves the depth back to the floor (4 -> 2 ->
        // 1) and the shrink counter records both steps.
        let q: FrameQueue<u32> =
            FrameQueue::new(WriterQueue::Adaptive { start: 1, max: 4 });
        for i in 0..4u64 {
            q.push(NetMsg::Control(ControlMsg::Probe { context: ContextId(i), round: i }))
                .expect("queue open");
        }
        let (_, cap, grows, _) = q.snapshot();
        assert_eq!((cap, grows), (4, 2), "burst grew to the ceiling");
        for _ in 0..4 {
            q.pop().unwrap();
        }
        for i in 0..80u64 {
            q.push(NetMsg::Control(ControlMsg::Probe { context: ContextId(i), round: i }))
                .expect("queue open");
            q.pop().unwrap();
        }
        let (_, cap, _, shrinks) = q.snapshot();
        assert_eq!(cap, 1, "depth decayed back to the configured floor");
        assert_eq!(shrinks, 2, "4 -> 2 -> 1");
        // The floor holds: further calm pops must not shrink below it.
        for i in 0..80u64 {
            q.push(NetMsg::Control(ControlMsg::Probe { context: ContextId(i), round: i }))
                .expect("queue open");
            q.pop().unwrap();
        }
        let (_, cap, _, shrinks) = q.snapshot();
        assert_eq!((cap, shrinks), (1, 2));
    }

    #[test]
    fn fixed_frame_queue_never_grows() {
        // A fixed depth-2 queue must block (not grow) when full: verify
        // with a consumer thread that drains after a delay.
        let q: Arc<FrameQueue<u32>> = Arc::new(FrameQueue::new(WriterQueue::Fixed(2)));
        let q2 = Arc::clone(&q);
        let consumer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            let mut got = Vec::new();
            while let Some(NetMsg::Control(ControlMsg::Probe { context, .. })) = q2.pop() {
                got.push(context.raw());
            }
            got
        });
        for i in 0..6u64 {
            q.push(NetMsg::Control(ControlMsg::Probe { context: ContextId(i), round: i }))
                .expect("queue open");
        }
        let (_, cap, grows, shrinks) = q.snapshot();
        assert_eq!((cap, grows), (2, 0), "fixed queue must not grow");
        assert_eq!(shrinks, 0, "fixed queue must not shrink");
        q.close();
        assert_eq!(consumer.join().unwrap(), (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn adaptive_writer_queue_tcp_delivers_fifo() {
        // End to end over sockets: adaptive queues grow under burst but
        // deliver everything in order, and the telemetry reports the
        // doubling steps and the live (grown) depth.
        let opts = TcpOptions {
            writer_queue: WriterQueue::Adaptive { start: 1, max: 64 },
            ..TcpOptions::default()
        };
        let (t1, t2) = tcp_pair(opts, opts);
        assert_eq!(t1.telemetry().queue_depth, 1, "initial depth before any writer");
        const N: u64 = 100;
        for i in 0..N {
            t1.send(
                AgentId(2),
                NetMsg::Control(ControlMsg::Probe { context: ContextId(i), round: i }),
            )
            .unwrap();
        }
        let t = t1.telemetry();
        assert!(t.queue_depth >= 1 && t.queue_depth <= 64);
        // 1 -> 64 is six doublings; any further grow needs a decay step
        // first (the writer draining fast enough to trigger the calm
        // streak), so the step counts bound each other.
        assert!(
            t.queue_grows <= 6 + t.queue_shrinks,
            "grows {} > 6 + shrinks {}",
            t.queue_grows,
            t.queue_shrinks
        );
        for i in 0..N {
            match t2.recv_timeout(Duration::from_secs(5)).expect("frame") {
                NetMsg::Control(ControlMsg::Probe { context, .. }) => {
                    assert_eq!(context, ContextId(i), "FIFO violated");
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn batch_chunking_splits_without_reencoding_property() {
        // The zero-re-encode chunker must, for any batch and any frame
        // limit, produce chunks that (a) each fit the limit, (b) decode,
        // (c) reassemble the events in order, and (d) carry the sync
        // flush, space ops and bound on the final chunk only.
        crate::testkit::check("batch chunking", 60, |rng| {
            let codec = if rng.chance(0.5) { WireCodec::Json } else { WireCodec::Binary };
            let events: Vec<Event<u32>> = (0..rng.range(1, 40)).map(|_| rand_event(rng)).collect();
            let sync: Vec<SyncMsg> = (0..rng.below(3)).map(|_| rand_sync(rng)).collect();
            let space: Vec<SpaceMsg> = (0..rng.below(3)).map(|_| rand_space(rng)).collect();
            let bound = if rng.chance(0.8) { Some(rand_time(rng)) } else { None };
            let msg = NetMsg::WindowBatch {
                context: ContextId(rng.below(4)),
                from: AgentId(rng.below(8)),
                events: events.clone(),
                sync: sync.clone(),
                space: space.clone(),
                bound,
            };
            let max_frame = 200 + rng.below(400) as usize;
            let mut frames = Vec::new();
            encode_split(codec, max_frame, msg, &mut frames)
                .map_err(|e| format!("split failed: {e:#}"))?;
            let mut got_events = Vec::new();
            let mut got_sync = Vec::new();
            let mut got_space = Vec::new();
            let mut got_bound = None;
            for (i, frame) in frames.iter().enumerate() {
                if frame.len() > max_frame {
                    return Err(format!("chunk {i} is {} bytes > {max_frame}", frame.len()));
                }
                let m: NetMsg<u32> = decode_msg(codec, frame)
                    .map_err(|e| format!("chunk {i} did not decode: {e:#}"))?;
                match m {
                    NetMsg::WindowBatch { events, sync, space, bound, .. } => {
                        let last = i == frames.len() - 1;
                        if !last && (!sync.is_empty() || !space.is_empty() || bound.is_some()) {
                            return Err(format!("non-final chunk {i} carries tail data"));
                        }
                        got_events.extend(events);
                        got_sync.extend(sync);
                        got_space.extend(space);
                        got_bound = bound;
                    }
                    other => return Err(format!("chunk {i} decoded to {other:?}")),
                }
            }
            if got_events.iter().map(|e| e.payload).collect::<Vec<_>>()
                != events.iter().map(|e| e.payload).collect::<Vec<_>>()
            {
                return Err("events lost or reordered".into());
            }
            if got_sync != sync || got_space != space || got_bound != bound {
                return Err("sync/space/bound did not survive the split".into());
            }
            Ok(())
        });
    }

    #[test]
    fn tcp_roundtrip_two_endpoints() {
        let addr1: SocketAddr = "127.0.0.1:39121".parse().unwrap();
        let addr2: SocketAddr = "127.0.0.1:39122".parse().unwrap();
        let peers: HashMap<AgentId, SocketAddr> = [(AgentId(1), addr1), (AgentId(2), addr2)]
            .into_iter()
            .collect();
        let t1: TcpTransport<u32> = TcpTransport::bind(AgentId(1), addr1, peers.clone()).unwrap();
        let t2: TcpTransport<u32> = TcpTransport::bind(AgentId(2), addr2, peers).unwrap();

        t1.send(
            AgentId(2),
            NetMsg::Event {
                context: ContextId(1),
                event: Event {
                    time: SimTime::new(9.0),
                    tie: (1, 1),
                    src_agent: AgentId(1),
                    src_lp: LpId(1),
                    dst_lp: LpId(2),
                    payload: 7u32,
                },
                bound: SimTime::new(9.0),
            },
        )
        .unwrap();
        match t2.recv_timeout(Duration::from_secs(5)).unwrap() {
            NetMsg::Event { event, .. } => {
                assert_eq!(event.payload, 7);
                assert_eq!(event.time, SimTime::new(9.0));
            }
            other => panic!("unexpected {other:?}"),
        }

        // Reply direction.
        t2.send(AgentId(1), NetMsg::Control(ControlMsg::Shutdown))
            .unwrap();
        assert!(matches!(
            t1.recv_timeout(Duration::from_secs(5)).unwrap(),
            NetMsg::Control(ControlMsg::Shutdown)
        ));
    }
}
