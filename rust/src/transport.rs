//! Agent-to-agent message transport.
//!
//! The framework runs in two deployment modes:
//!
//! * **In-process** ([`InProcNetwork`]) — every agent is a thread in one OS
//!   process; messages travel over `std::sync::mpsc` channels.  This is the
//!   default for tests, benches and single-machine studies.
//! * **TCP** ([`TcpTransport`]) — agents are separate OS processes
//!   (possibly on different hosts); messages are length-prefixed JSON
//!   frames over persistent sockets.  Payloads must implement [`Wire`].
//!
//! Both implement [`Transport`], so the engine/agent layers are agnostic.
//! Channels are FIFO per (src, dst) pair — the property the conservative
//! protocol relies on (a channel's head timestamp bounds the channel).

use std::collections::HashMap;
use std::io::{Read, Write as IoWrite};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::engine::{Event, SimTime, SyncMsg};
use crate::util::json::Json;
use crate::util::{AgentId, ContextId, LpId};

// ---------------------------------------------------------------------------
// Messages
// ---------------------------------------------------------------------------

/// Control-plane messages (deployment, termination detection, monitoring).
#[derive(Clone, Debug, PartialEq)]
pub enum ControlMsg {
    /// Leader -> agent: install an LP of `kind` with JSON params.
    DeployLp {
        context: ContextId,
        lp: LpId,
        kind: String,
        params: Json,
    },
    /// Leader -> agent: full LP->agent routing table for a context.
    RoutingTable {
        context: ContextId,
        routes: Vec<(LpId, AgentId)>,
    },
    /// Leader -> agent: inject a bootstrap event.
    Bootstrap {
        context: ContextId,
        time: SimTime,
        dst: LpId,
        payload: Json,
    },
    /// Leader -> agent: begin executing a context.  `participants` is the
    /// set of agents actually hosting LPs of this context — only they take
    /// part in conservative synchronization (a fleet member with no LPs
    /// would otherwise be dead weight the demand protocol keeps polling).
    StartRun {
        context: ContextId,
        participants: Vec<AgentId>,
    },
    /// Termination detection probe (double-count algorithm).
    Probe { context: ContextId, round: u64 },
    /// Agent -> leader: probe answer (idle?, #sent, #received, lvt,
    /// earliest pending event, safe windows executed).
    ProbeReply {
        context: ContextId,
        round: u64,
        from: AgentId,
        idle: bool,
        sent: u64,
        received: u64,
        lvt: SimTime,
        next_event: SimTime,
        /// Total safe windows this agent has executed for the context —
        /// the termination detector's progress signal at window
        /// granularity.
        windows: u64,
    },
    /// Leader -> agents: proven GVT lower bound (quiescent probe round).
    GvtUpdate { context: ContextId, gvt: SimTime },
    /// Leader -> agents: context finished; tear down and report stats.
    EndRun { context: ContextId },
    /// Agent -> leader: final per-agent statistics (JSON-encoded).
    FinalStats {
        context: ContextId,
        from: AgentId,
        stats: Json,
    },
    /// Agent -> leader: published simulation result record.
    Result {
        context: ContextId,
        kind: String,
        record: Json,
    },
    /// Monitoring: an agent's published performance sample.
    PerfSample { from: AgentId, value: f64, load: Json },
    /// Graceful process shutdown (TCP mode).
    Shutdown,
}

/// Everything that can travel between agents.
#[derive(Clone, Debug)]
pub enum NetMsg<P> {
    /// A simulation event, carrying the sender's current per-destination
    /// safe bound as a piggybacked null message (classic CMB optimization:
    /// every event refreshes the receiver's LVT-queue entry for free).
    Event {
        context: ContextId,
        event: Event<P>,
        bound: SimTime,
    },
    Sync {
        context: ContextId,
        from: AgentId,
        msg: SyncMsg,
    },
    Space(crate::space::SpaceMsg),
    Control(ControlMsg),
}

// ---------------------------------------------------------------------------
// Transport trait
// ---------------------------------------------------------------------------

/// A bidirectional, FIFO-per-channel message fabric for one agent.
pub trait Transport<P>: Send {
    /// This endpoint's agent id.
    fn me(&self) -> AgentId;

    /// All agents reachable (including self).
    fn agents(&self) -> Vec<AgentId>;

    /// Send a message to one agent.
    fn send(&self, to: AgentId, msg: NetMsg<P>) -> Result<()>;

    /// Receive the next message for this agent, waiting up to `timeout`.
    fn recv_timeout(&self, timeout: Duration) -> Option<NetMsg<P>>;

    /// Non-blocking drain of everything currently queued.
    fn drain(&self) -> Vec<NetMsg<P>> {
        let mut out = Vec::new();
        while let Some(m) = self.recv_timeout(Duration::ZERO) {
            out.push(m);
        }
        out
    }

    /// Send to every other agent.
    fn broadcast(&self, msg: NetMsg<P>) -> Result<()>
    where
        P: Clone,
    {
        for a in self.agents() {
            if a != self.me() {
                self.send(a, msg.clone())?;
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// In-process transport
// ---------------------------------------------------------------------------

struct InProcShared<P> {
    inboxes: RwLock<HashMap<AgentId, Sender<NetMsg<P>>>>,
    /// Per-sender delivery counters (message-count metrics for benches).
    sent: Mutex<HashMap<AgentId, u64>>,
}

/// Factory for a set of connected in-process endpoints.
pub struct InProcNetwork<P> {
    shared: Arc<InProcShared<P>>,
}

impl<P: Send + 'static> InProcNetwork<P> {
    pub fn new() -> Self {
        InProcNetwork {
            shared: Arc::new(InProcShared {
                inboxes: RwLock::new(HashMap::new()),
                sent: Mutex::new(HashMap::new()),
            }),
        }
    }

    /// Create the endpoint for `agent`.  Panics if the id is taken.
    pub fn endpoint(&self, agent: AgentId) -> InProcEndpoint<P> {
        let (tx, rx) = channel();
        let mut inboxes = self.shared.inboxes.write().unwrap();
        assert!(
            inboxes.insert(agent, tx).is_none(),
            "duplicate agent {agent}"
        );
        InProcEndpoint {
            me: agent,
            shared: Arc::clone(&self.shared),
            inbox: Mutex::new(rx),
        }
    }

    /// Total messages sent through the fabric (all endpoints).
    pub fn total_sent(&self) -> u64 {
        self.shared.sent.lock().unwrap().values().sum()
    }
}

impl<P: Send + 'static> Default for InProcNetwork<P> {
    fn default() -> Self {
        Self::new()
    }
}

/// One agent's endpoint on an [`InProcNetwork`].
pub struct InProcEndpoint<P> {
    me: AgentId,
    shared: Arc<InProcShared<P>>,
    inbox: Mutex<Receiver<NetMsg<P>>>,
}

impl<P: Send + 'static> Transport<P> for InProcEndpoint<P> {
    fn me(&self) -> AgentId {
        self.me
    }

    fn agents(&self) -> Vec<AgentId> {
        let mut v: Vec<AgentId> = self.shared.inboxes.read().unwrap().keys().copied().collect();
        v.sort();
        v
    }

    fn send(&self, to: AgentId, msg: NetMsg<P>) -> Result<()> {
        let inboxes = self.shared.inboxes.read().unwrap();
        let tx = inboxes
            .get(&to)
            .ok_or_else(|| anyhow!("unknown agent {to}"))?;
        tx.send(msg).map_err(|_| anyhow!("agent {to} hung up"))?;
        *self.shared.sent.lock().unwrap().entry(self.me).or_insert(0) += 1;
        Ok(())
    }

    fn recv_timeout(&self, timeout: Duration) -> Option<NetMsg<P>> {
        let rx = self.inbox.lock().unwrap();
        if timeout.is_zero() {
            rx.try_recv().ok()
        } else {
            rx.recv_timeout(timeout).ok()
        }
    }
}

// ---------------------------------------------------------------------------
// Wire encoding (TCP mode)
// ---------------------------------------------------------------------------

/// JSON-encodable payloads (needed only for the TCP transport; the
/// in-process transport moves values directly).
pub trait Wire: Sized {
    fn to_json(&self) -> Json;
    fn from_json(j: &Json) -> Result<Self>;
}

impl Wire for u32 {
    fn to_json(&self) -> Json {
        Json::num(*self as f64)
    }
    fn from_json(j: &Json) -> Result<Self> {
        j.as_u64()
            .map(|v| v as u32)
            .ok_or_else(|| anyhow!("expected number"))
    }
}

pub(crate) fn time_to_json(t: SimTime) -> Json {
    if t.0 == f64::INFINITY {
        Json::str("inf")
    } else if t.0 == f64::NEG_INFINITY {
        Json::str("-inf")
    } else {
        Json::num(t.0)
    }
}

pub(crate) fn time_from_json(j: &Json) -> Result<SimTime> {
    match j {
        Json::Num(n) => Ok(SimTime::new(*n)),
        Json::Str(s) if s == "inf" => Ok(SimTime::INF),
        Json::Str(s) if s == "-inf" => Ok(SimTime::NEG_INF),
        _ => bail!("bad time {j}"),
    }
}

fn event_to_json<P: Wire>(e: &Event<P>) -> Json {
    Json::obj(vec![
        ("t", time_to_json(e.time)),
        ("tie0", Json::num(e.tie.0 as f64)),
        ("tie1", Json::num(e.tie.1 as f64)),
        ("sa", Json::num(e.src_agent.raw() as f64)),
        ("sl", Json::num(e.src_lp.raw() as f64)),
        ("dl", Json::num(e.dst_lp.raw() as f64)),
        ("p", e.payload.to_json()),
    ])
}

fn event_from_json<P: Wire>(j: &Json) -> Result<Event<P>> {
    Ok(Event {
        time: time_from_json(j.get("t").context("t")?)?,
        tie: (
            j.get("tie0").and_then(Json::as_u64).context("tie0")?,
            j.get("tie1").and_then(Json::as_u64).context("tie1")?,
        ),
        src_agent: AgentId(j.get("sa").and_then(Json::as_u64).context("sa")?),
        src_lp: LpId(j.get("sl").and_then(Json::as_u64).context("sl")?),
        dst_lp: LpId(j.get("dl").and_then(Json::as_u64).context("dl")?),
        payload: P::from_json(j.get("p").context("p")?)?,
    })
}

fn sync_to_json(m: &SyncMsg) -> Json {
    match m {
        SyncMsg::LvtRequest { need, lvt } => Json::obj(vec![
            ("k", Json::str("req")),
            ("need", time_to_json(*need)),
            ("lvt", time_to_json(*lvt)),
        ]),
        SyncMsg::LvtAnnounce { bound } => Json::obj(vec![
            ("k", Json::str("ann")),
            ("bound", time_to_json(*bound)),
        ]),
    }
}

fn sync_from_json(j: &Json) -> Result<SyncMsg> {
    match j.get("k").and_then(Json::as_str) {
        Some("req") => Ok(SyncMsg::LvtRequest {
            need: time_from_json(j.get("need").context("need")?)?,
            lvt: time_from_json(j.get("lvt").context("lvt")?)?,
        }),
        Some("ann") => Ok(SyncMsg::LvtAnnounce {
            bound: time_from_json(j.get("bound").context("bound")?)?,
        }),
        _ => bail!("bad sync msg {j}"),
    }
}

fn control_to_json(c: &ControlMsg) -> Json {
    use ControlMsg::*;
    match c {
        DeployLp {
            context,
            lp,
            kind,
            params,
        } => Json::obj(vec![
            ("k", Json::str("deploy")),
            ("ctx", Json::num(context.raw() as f64)),
            ("lp", Json::num(lp.raw() as f64)),
            ("kind", Json::str(kind.clone())),
            ("params", params.clone()),
        ]),
        RoutingTable { context, routes } => Json::obj(vec![
            ("k", Json::str("routes")),
            ("ctx", Json::num(context.raw() as f64)),
            (
                "routes",
                Json::arr(routes.iter().map(|(l, a)| {
                    Json::arr([Json::num(l.raw() as f64), Json::num(a.raw() as f64)])
                })),
            ),
        ]),
        Bootstrap {
            context,
            time,
            dst,
            payload,
        } => Json::obj(vec![
            ("k", Json::str("bootstrap")),
            ("ctx", Json::num(context.raw() as f64)),
            ("t", time_to_json(*time)),
            ("dst", Json::num(dst.raw() as f64)),
            ("p", payload.clone()),
        ]),
        StartRun {
            context,
            participants,
        } => Json::obj(vec![
            ("k", Json::str("start")),
            ("ctx", Json::num(context.raw() as f64)),
            (
                "parts",
                Json::arr(participants.iter().map(|a| Json::num(a.raw() as f64))),
            ),
        ]),
        Probe { context, round } => Json::obj(vec![
            ("k", Json::str("probe")),
            ("ctx", Json::num(context.raw() as f64)),
            ("round", Json::num(*round as f64)),
        ]),
        ProbeReply {
            context,
            round,
            from,
            idle,
            sent,
            received,
            lvt,
            next_event,
            windows,
        } => Json::obj(vec![
            ("k", Json::str("probe-reply")),
            ("ctx", Json::num(context.raw() as f64)),
            ("round", Json::num(*round as f64)),
            ("from", Json::num(from.raw() as f64)),
            ("idle", Json::Bool(*idle)),
            ("sent", Json::num(*sent as f64)),
            ("received", Json::num(*received as f64)),
            ("lvt", time_to_json(*lvt)),
            ("next", time_to_json(*next_event)),
            ("win", Json::num(*windows as f64)),
        ]),
        GvtUpdate { context, gvt } => Json::obj(vec![
            ("k", Json::str("gvt")),
            ("ctx", Json::num(context.raw() as f64)),
            ("gvt", time_to_json(*gvt)),
        ]),
        EndRun { context } => Json::obj(vec![
            ("k", Json::str("end")),
            ("ctx", Json::num(context.raw() as f64)),
        ]),
        FinalStats {
            context,
            from,
            stats,
        } => Json::obj(vec![
            ("k", Json::str("stats")),
            ("ctx", Json::num(context.raw() as f64)),
            ("from", Json::num(from.raw() as f64)),
            ("stats", stats.clone()),
        ]),
        Result {
            context,
            kind,
            record,
        } => Json::obj(vec![
            ("k", Json::str("result")),
            ("ctx", Json::num(context.raw() as f64)),
            ("kind", Json::str(kind.clone())),
            ("record", record.clone()),
        ]),
        PerfSample { from, value, load } => Json::obj(vec![
            ("k", Json::str("perf")),
            ("from", Json::num(from.raw() as f64)),
            ("value", Json::num(*value)),
            ("load", load.clone()),
        ]),
        Shutdown => Json::obj(vec![("k", Json::str("shutdown"))]),
    }
}

fn control_from_json(j: &Json) -> Result<ControlMsg> {
    let ctx = || -> Result<ContextId> {
        Ok(ContextId(j.get("ctx").and_then(Json::as_u64).context("ctx")?))
    };
    match j.get("k").and_then(Json::as_str) {
        Some("deploy") => Ok(ControlMsg::DeployLp {
            context: ctx()?,
            lp: LpId(j.get("lp").and_then(Json::as_u64).context("lp")?),
            kind: j
                .get("kind")
                .and_then(Json::as_str)
                .context("kind")?
                .to_string(),
            params: j.get("params").context("params")?.clone(),
        }),
        Some("routes") => {
            let mut routes = Vec::new();
            for r in j.get("routes").and_then(Json::as_arr).context("routes")? {
                let pair = r.as_arr().context("route pair")?;
                routes.push((
                    LpId(pair[0].as_u64().context("lp")?),
                    AgentId(pair[1].as_u64().context("agent")?),
                ));
            }
            Ok(ControlMsg::RoutingTable {
                context: ctx()?,
                routes,
            })
        }
        Some("bootstrap") => Ok(ControlMsg::Bootstrap {
            context: ctx()?,
            time: time_from_json(j.get("t").context("t")?)?,
            dst: LpId(j.get("dst").and_then(Json::as_u64).context("dst")?),
            payload: j.get("p").context("p")?.clone(),
        }),
        Some("start") => Ok(ControlMsg::StartRun {
            context: ctx()?,
            participants: j
                .get("parts")
                .and_then(Json::as_arr)
                .context("parts")?
                .iter()
                .filter_map(Json::as_u64)
                .map(AgentId)
                .collect(),
        }),
        Some("probe") => Ok(ControlMsg::Probe {
            context: ctx()?,
            round: j.get("round").and_then(Json::as_u64).context("round")?,
        }),
        Some("probe-reply") => Ok(ControlMsg::ProbeReply {
            context: ctx()?,
            round: j.get("round").and_then(Json::as_u64).context("round")?,
            from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
            idle: j.get("idle").and_then(Json::as_bool).context("idle")?,
            sent: j.get("sent").and_then(Json::as_u64).context("sent")?,
            received: j
                .get("received")
                .and_then(Json::as_u64)
                .context("received")?,
            lvt: time_from_json(j.get("lvt").context("lvt")?)?,
            next_event: time_from_json(j.get("next").context("next")?)?,
            // Absent in pre-window frames; default keeps mixed fleets
            // decoding.
            windows: j.get("win").and_then(Json::as_u64).unwrap_or(0),
        }),
        Some("gvt") => Ok(ControlMsg::GvtUpdate {
            context: ctx()?,
            gvt: time_from_json(j.get("gvt").context("gvt")?)?,
        }),
        Some("end") => Ok(ControlMsg::EndRun { context: ctx()? }),
        Some("stats") => Ok(ControlMsg::FinalStats {
            context: ctx()?,
            from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
            stats: j.get("stats").context("stats")?.clone(),
        }),
        Some("result") => Ok(ControlMsg::Result {
            context: ctx()?,
            kind: j
                .get("kind")
                .and_then(Json::as_str)
                .context("kind")?
                .to_string(),
            record: j.get("record").context("record")?.clone(),
        }),
        Some("perf") => Ok(ControlMsg::PerfSample {
            from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
            value: j.get("value").and_then(Json::as_f64).context("value")?,
            load: j.get("load").context("load")?.clone(),
        }),
        Some("shutdown") => Ok(ControlMsg::Shutdown),
        _ => bail!("bad control msg {j}"),
    }
}

/// Full NetMsg encoding.
pub fn msg_to_json<P: Wire>(m: &NetMsg<P>) -> Json {
    match m {
        NetMsg::Event {
            context,
            event,
            bound,
        } => Json::obj(vec![
            ("k", Json::str("event")),
            ("ctx", Json::num(context.raw() as f64)),
            ("ev", event_to_json(event)),
            ("b", time_to_json(*bound)),
        ]),
        NetMsg::Sync { context, from, msg } => Json::obj(vec![
            ("k", Json::str("sync")),
            ("ctx", Json::num(context.raw() as f64)),
            ("from", Json::num(from.raw() as f64)),
            ("msg", sync_to_json(msg)),
        ]),
        NetMsg::Space(op) => Json::obj(vec![("k", Json::str("space")), ("op", op.to_json())]),
        NetMsg::Control(c) => {
            Json::obj(vec![("k", Json::str("control")), ("c", control_to_json(c))])
        }
    }
}

pub fn msg_from_json<P: Wire>(j: &Json) -> Result<NetMsg<P>> {
    match j.get("k").and_then(Json::as_str) {
        Some("event") => Ok(NetMsg::Event {
            context: ContextId(j.get("ctx").and_then(Json::as_u64).context("ctx")?),
            event: event_from_json(j.get("ev").context("ev")?)?,
            bound: time_from_json(j.get("b").context("b")?)?,
        }),
        Some("sync") => Ok(NetMsg::Sync {
            context: ContextId(j.get("ctx").and_then(Json::as_u64).context("ctx")?),
            from: AgentId(j.get("from").and_then(Json::as_u64).context("from")?),
            msg: sync_from_json(j.get("msg").context("msg")?)?,
        }),
        Some("space") => Ok(NetMsg::Space(crate::space::SpaceMsg::from_json(
            j.get("op").context("op")?,
        )?)),
        Some("control") => Ok(NetMsg::Control(control_from_json(
            j.get("c").context("c")?,
        )?)),
        _ => bail!("bad net msg {j}"),
    }
}

// ---------------------------------------------------------------------------
// TCP transport
// ---------------------------------------------------------------------------

/// Length-prefixed frame I/O.
fn write_frame(stream: &mut TcpStream, bytes: &[u8]) -> Result<()> {
    let len = (bytes.len() as u32).to_be_bytes();
    stream.write_all(&len)?;
    stream.write_all(bytes)?;
    stream.flush()?;
    Ok(())
}

fn read_frame(stream: &mut TcpStream) -> Result<Vec<u8>> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len)?;
    let n = u32::from_be_bytes(len) as usize;
    if n > 64 << 20 {
        bail!("frame too large: {n}");
    }
    let mut buf = vec![0u8; n];
    stream.read_exact(&mut buf)?;
    Ok(buf)
}

/// TCP endpoint: one listener for inbound peers, one persistent outbound
/// socket per peer (established lazily); reader threads funnel frames into
/// a single inbox channel.
pub struct TcpTransport<P> {
    me: AgentId,
    peers: HashMap<AgentId, SocketAddr>,
    outbound: Mutex<HashMap<AgentId, TcpStream>>,
    inbox: Mutex<Receiver<NetMsg<P>>>,
    inbox_tx: Sender<NetMsg<P>>,
    _listener: std::thread::JoinHandle<()>,
}

impl<P: Wire + Send + 'static> TcpTransport<P> {
    /// Bind `bind_addr` for `me` and remember the full peer address map
    /// (including self).
    pub fn bind(
        me: AgentId,
        bind_addr: SocketAddr,
        peers: HashMap<AgentId, SocketAddr>,
    ) -> Result<Self> {
        let listener =
            TcpListener::bind(bind_addr).with_context(|| format!("bind {bind_addr} for {me}"))?;
        let (tx, rx) = channel();
        let tx_accept = tx.clone();
        let handle = std::thread::Builder::new()
            .name(format!("dsim-tcp-accept-{me}"))
            .spawn(move || {
                for stream in listener.incoming() {
                    let Ok(mut stream) = stream else { break };
                    let tx = tx_accept.clone();
                    std::thread::spawn(move || loop {
                        match read_frame(&mut stream) {
                            Ok(bytes) => {
                                let Ok(text) = std::str::from_utf8(&bytes) else { break };
                                match Json::parse(text)
                                    .map_err(anyhow::Error::from)
                                    .and_then(|j| msg_from_json::<P>(&j))
                                {
                                    Ok(msg) => {
                                        if tx.send(msg).is_err() {
                                            break;
                                        }
                                    }
                                    Err(e) => {
                                        log::error!("bad frame: {e}");
                                        break;
                                    }
                                }
                            }
                            Err(_) => break,
                        }
                    });
                }
            })?;
        Ok(TcpTransport {
            me,
            peers,
            outbound: Mutex::new(HashMap::new()),
            inbox: Mutex::new(rx),
            inbox_tx: tx,
            _listener: handle,
        })
    }

    fn connect(&self, to: AgentId) -> Result<TcpStream> {
        let addr = self
            .peers
            .get(&to)
            .ok_or_else(|| anyhow!("unknown peer {to}"))?;
        // Retry briefly: peers race to bind at startup.
        let mut last = None;
        for _ in 0..50 {
            match TcpStream::connect(addr) {
                Ok(s) => {
                    s.set_nodelay(true).ok();
                    return Ok(s);
                }
                Err(e) => {
                    last = Some(e);
                    std::thread::sleep(Duration::from_millis(100));
                }
            }
        }
        Err(anyhow!("connect {to} at {addr}: {last:?}"))
    }
}

impl<P: Wire + Clone + Send + 'static> Transport<P> for TcpTransport<P> {
    fn me(&self) -> AgentId {
        self.me
    }

    fn agents(&self) -> Vec<AgentId> {
        let mut v: Vec<AgentId> = self.peers.keys().copied().collect();
        v.sort();
        v
    }

    fn send(&self, to: AgentId, msg: NetMsg<P>) -> Result<()> {
        if to == self.me {
            // Loopback without a socket.
            self.inbox_tx
                .send(msg)
                .map_err(|_| anyhow!("self inbox closed"))?;
            return Ok(());
        }
        let text = msg_to_json(&msg).to_string();
        let mut outbound = self.outbound.lock().unwrap();
        if !outbound.contains_key(&to) {
            let s = self.connect(to)?;
            outbound.insert(to, s);
        }
        let stream = outbound.get_mut(&to).unwrap();
        if let Err(e) = write_frame(stream, text.as_bytes()) {
            // One reconnect attempt on a stale socket.
            log::warn!("resend to {to} after {e}");
            let mut s = self.connect(to)?;
            write_frame(&mut s, text.as_bytes())?;
            outbound.insert(to, s);
        }
        Ok(())
    }

    fn recv_timeout(&self, timeout: Duration) -> Option<NetMsg<P>> {
        let rx = self.inbox.lock().unwrap();
        if timeout.is_zero() {
            rx.try_recv().ok()
        } else {
            rx.recv_timeout(timeout).ok()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inproc_roundtrip_and_order() {
        let net: InProcNetwork<u32> = InProcNetwork::new();
        let a = net.endpoint(AgentId(1));
        let b = net.endpoint(AgentId(2));
        for i in 0..10u64 {
            a.send(
                AgentId(2),
                NetMsg::Control(ControlMsg::Probe {
                    context: ContextId(i),
                    round: 0,
                }),
            )
            .unwrap();
        }
        for i in 0..10u64 {
            match b.recv_timeout(Duration::from_secs(1)).unwrap() {
                NetMsg::Control(ControlMsg::Probe { context, .. }) => {
                    assert_eq!(context, ContextId(i)); // FIFO preserved
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(net.total_sent(), 10);
    }

    #[test]
    fn inproc_unknown_agent_errors() {
        let net: InProcNetwork<u32> = InProcNetwork::new();
        let a = net.endpoint(AgentId(1));
        assert!(a
            .send(AgentId(9), NetMsg::Control(ControlMsg::Shutdown))
            .is_err());
    }

    #[test]
    fn wire_event_roundtrip() {
        let ev = Event {
            time: SimTime::new(1.5),
            tie: (3, 42),
            src_agent: AgentId(3),
            src_lp: LpId(7),
            dst_lp: LpId(8),
            payload: 99u32,
        };
        let j = event_to_json(&ev);
        let back: Event<u32> = event_from_json(&j).unwrap();
        assert_eq!(back.time, ev.time);
        assert_eq!(back.tie, ev.tie);
        assert_eq!(back.payload, 99);
    }

    #[test]
    fn wire_sync_roundtrip_with_infinities() {
        for m in [
            SyncMsg::LvtRequest {
                need: SimTime::new(2.0),
                lvt: SimTime::NEG_INF,
            },
            SyncMsg::LvtAnnounce { bound: SimTime::INF },
        ] {
            let j = sync_to_json(&m);
            assert_eq!(sync_from_json(&j).unwrap(), m);
        }
    }

    #[test]
    fn wire_control_roundtrip() {
        let msgs = vec![
            ControlMsg::DeployLp {
                context: ContextId(1),
                lp: LpId(5),
                kind: "cpu".into(),
                params: Json::obj(vec![("power", Json::num(2.5))]),
            },
            ControlMsg::RoutingTable {
                context: ContextId(1),
                routes: vec![(LpId(1), AgentId(2)), (LpId(3), AgentId(4))],
            },
            ControlMsg::ProbeReply {
                context: ContextId(2),
                round: 7,
                from: AgentId(1),
                idle: true,
                sent: 10,
                received: 10,
                lvt: SimTime::new(3.5),
                next_event: SimTime::INF,
                windows: 42,
            },
            ControlMsg::GvtUpdate {
                context: ContextId(1),
                gvt: SimTime::new(4.5),
            },
            ControlMsg::Shutdown,
        ];
        for m in msgs {
            let j = control_to_json(&m);
            assert_eq!(control_from_json(&j).unwrap(), m);
        }
    }

    #[test]
    fn tcp_roundtrip_two_endpoints() {
        let addr1: SocketAddr = "127.0.0.1:39121".parse().unwrap();
        let addr2: SocketAddr = "127.0.0.1:39122".parse().unwrap();
        let peers: HashMap<AgentId, SocketAddr> = [(AgentId(1), addr1), (AgentId(2), addr2)]
            .into_iter()
            .collect();
        let t1: TcpTransport<u32> = TcpTransport::bind(AgentId(1), addr1, peers.clone()).unwrap();
        let t2: TcpTransport<u32> = TcpTransport::bind(AgentId(2), addr2, peers).unwrap();

        t1.send(
            AgentId(2),
            NetMsg::Event {
                context: ContextId(1),
                event: Event {
                    time: SimTime::new(9.0),
                    tie: (1, 1),
                    src_agent: AgentId(1),
                    src_lp: LpId(1),
                    dst_lp: LpId(2),
                    payload: 7u32,
                },
                bound: SimTime::new(9.0),
            },
        )
        .unwrap();
        match t2.recv_timeout(Duration::from_secs(5)).unwrap() {
            NetMsg::Event { event, .. } => {
                assert_eq!(event.payload, 7);
                assert_eq!(event.time, SimTime::new(9.0));
            }
            other => panic!("unexpected {other:?}"),
        }

        // Reply direction.
        t2.send(AgentId(1), NetMsg::Control(ControlMsg::Shutdown))
            .unwrap();
        assert!(matches!(
            t1.recv_timeout(Duration::from_secs(5)).unwrap(),
            NetMsg::Control(ControlMsg::Shutdown)
        ));
    }
}
