//! Scenario + deployment configuration.
//!
//! Configs are JSON files (the offline snapshot has no TOML crate; the
//! framework ships its own JSON implementation in [`crate::util::json`]).
//! A config names the workload, its parameters, and how to deploy it:
//! number of agents, sync protocol, worker threads, lookahead, compute
//! backend.  `dsim run <config.json>` drives everything from here.

use std::path::Path;
use std::str::FromStr;

use anyhow::{bail, Context, Result};

use crate::coordinator::adaptive::{WindowBudgetMode, WindowBudgetSpec};
use crate::engine::{EventQueueKind, ExecMode, SyncProtocol};
use crate::trace::TraceMode;
use crate::transport::{WireCodec, WriterQueue};
use crate::util::json::Json;
use crate::util::AgentId;

/// How the placement scheduler and network model evaluate their numeric
/// hot spots.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// PJRT executables compiled from the AOT artifacts (default when
    /// `artifacts/` is present).
    Pjrt,
    /// Pure-Rust fallback (identical algorithms, no XLA dependency).
    Native,
}

impl FromStr for BackendKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "pjrt" | "xla" => Ok(BackendKind::Pjrt),
            "native" | "rust" => Ok(BackendKind::Native),
            other => Err(format!("unknown backend '{other}' (pjrt|native)")),
        }
    }
}

/// Placement policy for LP groups (paper §4.1 vs baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// The paper's performance-value / shortest-path scheduler.
    PerfValue,
    /// Round-robin over agents (baseline).
    RoundRobin,
    /// Uniform random over agents (baseline).
    Random,
}

impl FromStr for PlacementPolicy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "perf" | "perf-value" | "paper" => Ok(PlacementPolicy::PerfValue),
            "rr" | "round-robin" => Ok(PlacementPolicy::RoundRobin),
            "random" | "rand" => Ok(PlacementPolicy::Random),
            other => Err(format!(
                "unknown placement policy '{other}' (perf|rr|random)"
            )),
        }
    }
}

/// What the launch leader does when a fleet member fails mid-run
/// (`deploy.on_failure`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OnFailure {
    /// Tear the fleet down and abort the run (the default, and the only
    /// behavior before checkpoints existed).
    #[default]
    Abort,
    /// Respawn the fleet and roll every member back to the latest
    /// committed coordinated checkpoint (from scratch if none committed
    /// yet), then resume.  Requires `deploy.checkpoint_windows > 0` to
    /// resume from anywhere but the start.
    Restart,
}

impl std::fmt::Display for OnFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OnFailure::Abort => write!(f, "abort"),
            OnFailure::Restart => write!(f, "restart"),
        }
    }
}

impl FromStr for OnFailure {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "abort" => Ok(OnFailure::Abort),
            "restart" => Ok(OnFailure::Restart),
            other => Err(format!("unknown on_failure '{other}' (abort|restart)")),
        }
    }
}

/// One scheduled fault in a [`FaultPlan`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The agent process exits hard (no AgentFailed frame, no cleanup) —
    /// equivalent to an external SIGKILL.
    KillAgent,
    /// The agent drops one inbound transport frame and treats the loss as
    /// a fatal local error (a poisoned connection).
    DropFrame,
    /// The agent sleeps `count` milliseconds before each outbound flush
    /// for one window — a slow writer, not a failure.
    DelayWriter,
    /// The agent skips its next `count` heartbeats — a silent-but-alive
    /// member the liveness monitor must flag.
    StallHeartbeat,
}

impl std::fmt::Display for FaultKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            FaultKind::KillAgent => "kill_agent",
            FaultKind::DropFrame => "drop_frame",
            FaultKind::DelayWriter => "delay_writer",
            FaultKind::StallHeartbeat => "stall_heartbeat",
        };
        write!(f, "{s}")
    }
}

impl FromStr for FaultKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "kill_agent" => Ok(FaultKind::KillAgent),
            "drop_frame" => Ok(FaultKind::DropFrame),
            "delay_writer" => Ok(FaultKind::DelayWriter),
            "stall_heartbeat" => Ok(FaultKind::StallHeartbeat),
            other => Err(format!(
                "unknown fault kind '{other}' \
                 (kill_agent|drop_frame|delay_writer|stall_heartbeat)"
            )),
        }
    }
}

/// One entry of a fault schedule: `kind` fires on `agent` when that
/// agent's executed-window counter reaches `at_window`, but only on fleet
/// launch attempt `on_attempt` (1 = the first launch; a restarted fleet
/// runs as attempt 2, so a kill scheduled for attempt 1 cannot re-fire
/// and wedge the recovery in a loop).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    pub kind: FaultKind,
    pub agent: AgentId,
    pub at_window: u64,
    /// Kind-specific magnitude: heartbeats to skip (`stall_heartbeat`),
    /// milliseconds of delay (`delay_writer`); ignored otherwise.
    pub count: u64,
    pub on_attempt: u64,
}

impl FaultSpec {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str(self.kind.to_string())),
            ("agent", Json::num(self.agent.raw() as f64)),
            ("at_window", Json::num(self.at_window as f64)),
            ("count", Json::num(self.count as f64)),
            ("on_attempt", Json::num(self.on_attempt as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<FaultSpec> {
        Ok(FaultSpec {
            kind: j
                .get("kind")
                .and_then(Json::as_str)
                .context("fault kind")?
                .parse()
                .map_err(anyhow::Error::msg)?,
            agent: AgentId(j.get("agent").and_then(Json::as_u64).context("fault agent")?),
            at_window: j
                .get("at_window")
                .and_then(Json::as_u64)
                .context("fault at_window")?,
            count: j.get("count").and_then(Json::as_u64).unwrap_or(1),
            on_attempt: j.get("on_attempt").and_then(Json::as_u64).unwrap_or(1),
        })
    }
}

/// A deterministic, replayable fault-injection schedule (the `faults`
/// scenario block).  Faults fire at *virtual* trigger points — an agent's
/// executed-window counter — never wall-clock timers, so a given scenario
/// file reproduces the same failure at the same point in every run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Reserved for future randomized schedules; recorded so two runs of
    /// the same plan can be compared.
    pub seed: u64,
    pub schedule: Vec<FaultSpec>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.schedule.is_empty()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            (
                "schedule",
                Json::arr(self.schedule.iter().map(FaultSpec::to_json)),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<FaultPlan> {
        Ok(FaultPlan {
            seed: j.get("seed").and_then(Json::as_u64).unwrap_or(0),
            schedule: j
                .get("schedule")
                .and_then(Json::as_arr)
                .context("faults.schedule")?
                .iter()
                .map(FaultSpec::from_json)
                .collect::<Result<Vec<_>>>()?,
        })
    }

    pub fn from_json_text(text: &str) -> Result<FaultPlan> {
        Self::from_json(&Json::parse(text).context("fault plan is not valid JSON")?)
    }
}

/// Deployment parameters.
#[derive(Clone, Debug)]
pub struct DeployConfig {
    /// Number of simulation agents.
    pub agents: usize,
    /// Worker threads per agent (0 = inline execution).
    pub workers: usize,
    /// Conservative sync variant.
    pub protocol: SyncProtocol,
    /// Scheduler granularity: safe-window batches ("window", default) or
    /// the per-timestamp baseline ("step").
    pub exec: ExecMode,
    /// Pending-event store: the global binary `heap` (default, the
    /// equivalence baseline) or the `ladder` calendar queue (O(1) amortized,
    /// built for 10⁵–10⁶ LPs).  Results are bit-identical either way —
    /// event keys are unique, so any correct priority queue pops in the
    /// same order.
    pub event_queue: EventQueueKind,
    /// Placement policy.
    pub placement: PlacementPolicy,
    /// Compute backend for scheduler/network math.
    pub backend: BackendKind,
    /// Model lookahead override (seconds of virtual time); None = derive
    /// from the scenario (min WAN latency).
    pub lookahead: Option<f64>,
    /// Window-batched wire protocol (default true): one frame per peer per
    /// window flush plus one per-window leader report, instead of one
    /// frame per message.  `false` restores the legacy protocol (mixed
    /// fleets, equivalence baselines).
    pub wire_batch: bool,
    /// Maximum accepted wire frame size in MiB (TCP transport).  Outbound
    /// window batches above the limit are split; inbound oversized frames
    /// are drained and skipped.  Records the fleet-wide value that every
    /// `dsim agent --max-frame-mib` must be launched with — limits must
    /// match across the fleet (a sender only splits against its *own*
    /// limit); in-process deployments move values directly and ignore it.
    pub max_frame_mib: usize,
    /// Frame body encoding on TCP deployments (`binary` default,
    /// `json` = pre-codec interop / on-the-wire debugging).  Chosen per
    /// *outbound* connection — receivers decode whatever each sender's
    /// preamble announces, so the knob records the fleet's intent rather
    /// than a hard constraint; in-process deployments move values
    /// directly and ignore it.
    pub wire_codec: WireCodec,
    /// Per-peer TCP writer-queue sizing policy: a number or `"fixed(N)"`
    /// pins the bound to N frames (>= 1, the historical behavior);
    /// `"adaptive"` starts shallow and doubles the bound from the
    /// occupancy high-water telemetry whenever a send finds the queue
    /// full, up to a ceiling.  Either way a full queue at the ceiling
    /// blocks the sending agent — backpressure, never loss.
    pub writer_queue_frames: WriterQueue,
    /// Per-window timestamp-budget policy: `"fixed(N)"` (default
    /// `fixed(16384)`, the historical constant) or `"adaptive"` — the
    /// feedback controller sized from transport backlog + window
    /// occupancy.  Results are identical either way; only window counts
    /// and latency change (see `coordinator::adaptive`).
    pub window_budget: WindowBudgetMode,
    /// Adaptive controller lower clamp / slow-start value (>= 1).
    pub window_budget_min: usize,
    /// Adaptive controller upper clamp (>= `window_budget_min`).
    pub window_budget_max: usize,
    /// GVT probe fallback cadence in milliseconds.  Probe rounds normally
    /// trigger on window-completion notifications; this timer only retries
    /// lost replies and bounds termination latency on a quiet fleet.
    pub probe_fallback_ms: u64,
    /// Agent liveness heartbeat period in milliseconds, 0 = off (the
    /// in-process default — threads in one process fail together, so the
    /// control plane has nothing extra to watch).  `dsim scenario launch`
    /// turns heartbeats on for its subprocess fleets (default 250 when
    /// unset) and aborts the run when an agent stays silent past the
    /// leader's deadline (8x the period, >= 2s).  Heartbeats are
    /// control-plane only and never perturb simulation results.
    pub heartbeat_ms: u64,
    /// Coordinated-checkpoint cadence for `dsim scenario launch` fleets:
    /// every N executed windows the leader drives a quiescent barrier and
    /// every agent writes its full engine state to disk.  0 (default) =
    /// checkpoints off.  In-process deployments ignore it.
    pub checkpoint_windows: u64,
    /// Live-telemetry cadence: every N *executed windows* each agent
    /// streams one `Telemetry` snapshot (LVT, window budget, writer-queue
    /// occupancy, wire traffic, event-queue depth) to the leader, which
    /// folds them into per-agent time-series in the run report (and the
    /// `--watch` view).  0 (default) = off.  The trigger is virtual
    /// progress, never wall clock, so results are identical either way.
    pub telemetry_windows: u64,
    /// Dual-clock tracing mode (see [`crate::trace`]): `off` (default),
    /// `virtual` (deterministic causal event trace), `wall` (phase
    /// profiler + scheduling spans) or `both`.  Capture is strictly
    /// observational — fingerprints are bit-identical with tracing on or
    /// off — and exports as Chrome trace-event JSON via
    /// `dsim scenario run|launch --trace out.json`.
    pub trace: TraceMode,
    /// Per-context span ring-buffer capacity (>= 1): tracing a
    /// million-LP run keeps the newest N spans and counts the dropped
    /// prefix instead of growing without bound.
    pub trace_buffer_spans: usize,
    /// Leader policy when a fleet member fails mid-run: `abort` (default)
    /// or `restart` (respawn + roll back to the latest checkpoint).
    pub on_failure: OnFailure,
    /// Total time a TCP writer keeps retrying a refused connection before
    /// declaring the peer unreachable, in milliseconds.
    pub connect_timeout_ms: u64,
    /// First TCP connect-retry delay, in milliseconds (doubles per
    /// attempt, capped at 1 s).
    pub connect_backoff_ms: u64,
    /// Directory holding the AOT artifacts.
    pub artifacts_dir: String,
}

impl DeployConfig {
    /// The window-budget policy as one value — the single assembly point
    /// for the three knobs, shared by validation and deployment so they
    /// can never drift apart.
    pub fn budget_spec(&self) -> WindowBudgetSpec {
        WindowBudgetSpec {
            mode: self.window_budget,
            min: self.window_budget_min,
            max: self.window_budget_max,
        }
    }

    /// Deploy-section sanity checks with actionable messages — shared by
    /// [`ScenarioConfig::validate`] and the declarative scenario loader
    /// ([`crate::scenario`]), so the two front doors can never drift.
    pub fn validate(&self) -> Result<()> {
        if self.agents == 0 {
            bail!("deploy.agents must be >= 1");
        }
        if self.agents > 64 {
            bail!("deploy.agents must be <= 64 (AOT placement artifact is N=64)");
        }
        if let Some(l) = self.lookahead {
            if l <= 0.0 {
                bail!("deploy.lookahead must be > 0 (conservative sync)");
            }
        }
        if !(1..=usize::MAX >> 20).contains(&self.max_frame_mib) {
            bail!(
                "deploy.max_frame_mib must be in 1..={} (MiB shifted to bytes must fit usize)",
                usize::MAX >> 20
            );
        }
        if let Err(e) = self.writer_queue_frames.validate() {
            bail!("deploy.{e}");
        }
        if let Err(e) = self.budget_spec().validate() {
            bail!("deploy.{e}");
        }
        if self.probe_fallback_ms == 0 {
            bail!("deploy.probe_fallback_ms must be >= 1");
        }
        if self.trace_buffer_spans == 0 {
            bail!("deploy.trace_buffer_spans must be >= 1");
        }
        if self.connect_timeout_ms == 0 {
            bail!("deploy.connect_timeout_ms must be >= 1");
        }
        if self.connect_backoff_ms == 0 {
            bail!("deploy.connect_backoff_ms must be >= 1");
        }
        Ok(())
    }
}

impl Default for DeployConfig {
    fn default() -> Self {
        DeployConfig {
            agents: 2,
            workers: 0,
            protocol: SyncProtocol::NullMessagesByDemand,
            exec: ExecMode::SafeWindow,
            event_queue: EventQueueKind::default(),
            placement: PlacementPolicy::PerfValue,
            backend: BackendKind::Native,
            lookahead: None,
            wire_batch: true,
            max_frame_mib: crate::transport::DEFAULT_MAX_FRAME_BYTES >> 20,
            wire_codec: WireCodec::default(),
            writer_queue_frames: WriterQueue::default(),
            window_budget: WindowBudgetSpec::default().mode,
            window_budget_min: WindowBudgetSpec::default().min,
            window_budget_max: WindowBudgetSpec::default().max,
            probe_fallback_ms: 2,
            heartbeat_ms: 0,
            checkpoint_windows: 0,
            telemetry_windows: 0,
            trace: TraceMode::Off,
            trace_buffer_spans: 65536,
            on_failure: OnFailure::Abort,
            connect_timeout_ms: crate::transport::DEFAULT_CONNECT_TIMEOUT_MS,
            connect_backoff_ms: crate::transport::DEFAULT_CONNECT_BACKOFF_MS,
            artifacts_dir: "artifacts".to_string(),
        }
    }
}

/// Workload parameters for the built-in scenario generators.
#[derive(Clone, Debug)]
pub struct WorkloadConfig {
    /// Generator name: "t0t1" | "farm" | "two-center".
    pub name: String,
    /// Regional centers (T1 count for t0t1).
    pub centers: usize,
    /// CPU units per center.
    pub cpus_per_center: usize,
    /// Jobs (analysis/production) per center.
    pub jobs_per_center: usize,
    /// T0->T1 WAN bandwidth, Mbps (the fig. 2 sweep parameter).
    pub wan_bandwidth_mbps: f64,
    /// WAN latency, virtual seconds (also the default lookahead).
    pub wan_latency_s: f64,
    /// Mean data volume per transfer, MB.
    pub transfer_mb: f64,
    /// Transfers per center for the replication study.
    pub transfers_per_center: usize,
    /// PRNG seed.
    pub seed: u64,
    /// MONARC-faithful per-transfer interrupt events in the WAN (fig. 2's
    /// event blow-up mechanism); false = batched re-plan (optimized).
    pub faithful_interrupts: bool,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            name: "t0t1".to_string(),
            centers: 4,
            cpus_per_center: 8,
            jobs_per_center: 32,
            wan_bandwidth_mbps: 622.0, // the paper-era transatlantic OC-12
            wan_latency_s: 0.05,
            transfer_mb: 500.0,
            transfers_per_center: 64,
            seed: 1,
            faithful_interrupts: false,
        }
    }
}

/// The full config: deployment + workload.
#[derive(Clone, Debug, Default)]
pub struct ScenarioConfig {
    pub deploy: DeployConfig,
    pub workload: WorkloadConfig,
}

fn get_f64(j: &Json, key: &str, default: f64) -> Result<f64> {
    match j.get(key) {
        None => Ok(default),
        Some(v) => v.as_f64().with_context(|| format!("field '{key}' must be a number")),
    }
}

fn get_usize(j: &Json, key: &str, default: usize) -> Result<usize> {
    match j.get(key) {
        None => Ok(default),
        Some(v) => Ok(v
            .as_u64()
            .with_context(|| format!("field '{key}' must be a non-negative integer"))?
            as usize),
    }
}

fn get_str<'a>(j: &'a Json, key: &str, default: &str) -> Result<String> {
    match j.get(key) {
        None => Ok(default.to_string()),
        Some(v) => Ok(v
            .as_str()
            .with_context(|| format!("field '{key}' must be a string"))?
            .to_string()),
    }
}

impl ScenarioConfig {
    /// Parse from JSON text.
    pub fn from_json_text(text: &str) -> Result<ScenarioConfig> {
        let j = Json::parse(text).context("config is not valid JSON")?;
        let d = j.get("deploy").cloned().unwrap_or(Json::obj(vec![]));
        let w = j.get("workload").cloned().unwrap_or(Json::obj(vec![]));
        let dd = DeployConfig::default();
        let wd = WorkloadConfig::default();

        let deploy = DeployConfig {
            agents: get_usize(&d, "agents", dd.agents)?,
            workers: get_usize(&d, "workers", dd.workers)?,
            protocol: get_str(&d, "protocol", "demand")?
                .parse()
                .map_err(anyhow::Error::msg)?,
            exec: get_str(&d, "exec", "window")?
                .parse()
                .map_err(anyhow::Error::msg)?,
            event_queue: get_str(&d, "event_queue", &dd.event_queue.to_string())?
                .parse()
                .map_err(anyhow::Error::msg)?,
            placement: get_str(&d, "placement", "perf")?
                .parse()
                .map_err(anyhow::Error::msg)?,
            backend: get_str(&d, "backend", "native")?
                .parse()
                .map_err(anyhow::Error::msg)?,
            lookahead: match d.get("lookahead") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_f64().context("lookahead must be a number")?),
            },
            wire_batch: d
                .get("wire_batch")
                .and_then(Json::as_bool)
                .unwrap_or(dd.wire_batch),
            max_frame_mib: get_usize(&d, "max_frame_mib", dd.max_frame_mib)?,
            wire_codec: get_str(&d, "wire_codec", &dd.wire_codec.to_string())?
                .parse()
                .map_err(anyhow::Error::msg)?,
            writer_queue_frames: match d.get("writer_queue_frames") {
                None => dd.writer_queue_frames,
                // Plain numbers stay valid (pre-adaptive configs).
                Some(v) => WriterQueue::from_json(v).map_err(anyhow::Error::msg)?,
            },
            window_budget: get_str(&d, "window_budget", &dd.window_budget.to_string())?
                .parse()
                .map_err(anyhow::Error::msg)?,
            window_budget_min: get_usize(&d, "window_budget_min", dd.window_budget_min)?,
            window_budget_max: get_usize(&d, "window_budget_max", dd.window_budget_max)?,
            probe_fallback_ms: get_usize(&d, "probe_fallback_ms", dd.probe_fallback_ms as usize)?
                as u64,
            heartbeat_ms: get_usize(&d, "heartbeat_ms", dd.heartbeat_ms as usize)? as u64,
            checkpoint_windows: get_usize(&d, "checkpoint_windows", dd.checkpoint_windows as usize)?
                as u64,
            telemetry_windows: get_usize(&d, "telemetry_windows", dd.telemetry_windows as usize)?
                as u64,
            trace: get_str(&d, "trace", &dd.trace.to_string())?
                .parse()
                .map_err(anyhow::Error::msg)?,
            trace_buffer_spans: get_usize(&d, "trace_buffer_spans", dd.trace_buffer_spans)?,
            on_failure: get_str(&d, "on_failure", &dd.on_failure.to_string())?
                .parse()
                .map_err(anyhow::Error::msg)?,
            connect_timeout_ms: get_usize(&d, "connect_timeout_ms", dd.connect_timeout_ms as usize)?
                as u64,
            connect_backoff_ms: get_usize(&d, "connect_backoff_ms", dd.connect_backoff_ms as usize)?
                as u64,
            artifacts_dir: get_str(&d, "artifacts_dir", &dd.artifacts_dir)?,
        };
        let workload = WorkloadConfig {
            name: get_str(&w, "name", &wd.name)?,
            centers: get_usize(&w, "centers", wd.centers)?,
            cpus_per_center: get_usize(&w, "cpus_per_center", wd.cpus_per_center)?,
            jobs_per_center: get_usize(&w, "jobs_per_center", wd.jobs_per_center)?,
            wan_bandwidth_mbps: get_f64(&w, "wan_bandwidth_mbps", wd.wan_bandwidth_mbps)?,
            wan_latency_s: get_f64(&w, "wan_latency_s", wd.wan_latency_s)?,
            transfer_mb: get_f64(&w, "transfer_mb", wd.transfer_mb)?,
            transfers_per_center: get_usize(&w, "transfers_per_center", wd.transfers_per_center)?,
            seed: get_usize(&w, "seed", wd.seed as usize)? as u64,
            faithful_interrupts: w
                .get("faithful_interrupts")
                .and_then(Json::as_bool)
                .unwrap_or(wd.faithful_interrupts),
        };
        let cfg = ScenarioConfig { deploy, workload };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<ScenarioConfig> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
        Self::from_json_text(&text)
    }

    /// Sanity checks with actionable messages.
    pub fn validate(&self) -> Result<()> {
        self.deploy.validate()?;
        if self.workload.centers == 0 {
            bail!("workload.centers must be >= 1");
        }
        if self.workload.wan_bandwidth_mbps <= 0.0 {
            bail!("workload.wan_bandwidth_mbps must be > 0");
        }
        if self.workload.wan_latency_s <= 0.0 {
            bail!("workload.wan_latency_s must be > 0 (it provides lookahead)");
        }
        if !["t0t1", "farm", "two-center", "large_grid"].contains(&self.workload.name.as_str()) {
            bail!(
                "unknown workload '{}' (t0t1|farm|two-center|large_grid)",
                self.workload.name
            );
        }
        Ok(())
    }

    /// Effective lookahead: explicit override or the WAN latency.
    pub fn lookahead(&self) -> f64 {
        self.deploy.lookahead.unwrap_or(self.workload.wan_latency_s)
    }

    /// Serialize (for golden tests / `dsim run --dump-config`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "deploy",
                Json::obj(vec![
                    ("agents", Json::num(self.deploy.agents as f64)),
                    ("workers", Json::num(self.deploy.workers as f64)),
                    ("protocol", Json::str(self.deploy.protocol.to_string())),
                    ("exec", Json::str(self.deploy.exec.to_string())),
                    (
                        "event_queue",
                        Json::str(self.deploy.event_queue.to_string()),
                    ),
                    (
                        "placement",
                        Json::str(match self.deploy.placement {
                            PlacementPolicy::PerfValue => "perf",
                            PlacementPolicy::RoundRobin => "rr",
                            PlacementPolicy::Random => "random",
                        }),
                    ),
                    (
                        "backend",
                        Json::str(match self.deploy.backend {
                            BackendKind::Pjrt => "pjrt",
                            BackendKind::Native => "native",
                        }),
                    ),
                    (
                        "lookahead",
                        match self.deploy.lookahead {
                            Some(l) => Json::num(l),
                            None => Json::Null,
                        },
                    ),
                    ("wire_batch", Json::Bool(self.deploy.wire_batch)),
                    (
                        "max_frame_mib",
                        Json::num(self.deploy.max_frame_mib as f64),
                    ),
                    ("wire_codec", Json::str(self.deploy.wire_codec.to_string())),
                    (
                        "writer_queue_frames",
                        // Fixed depths serialize as plain numbers (the
                        // pre-adaptive format); only `adaptive` needs the
                        // policy-string form.
                        match self.deploy.writer_queue_frames {
                            WriterQueue::Fixed(n) => Json::num(n as f64),
                            q => Json::str(q.to_string()),
                        },
                    ),
                    (
                        "window_budget",
                        Json::str(self.deploy.window_budget.to_string()),
                    ),
                    (
                        "window_budget_min",
                        Json::num(self.deploy.window_budget_min as f64),
                    ),
                    (
                        "window_budget_max",
                        Json::num(self.deploy.window_budget_max as f64),
                    ),
                    (
                        "probe_fallback_ms",
                        Json::num(self.deploy.probe_fallback_ms as f64),
                    ),
                    ("heartbeat_ms", Json::num(self.deploy.heartbeat_ms as f64)),
                    (
                        "checkpoint_windows",
                        Json::num(self.deploy.checkpoint_windows as f64),
                    ),
                    (
                        "telemetry_windows",
                        Json::num(self.deploy.telemetry_windows as f64),
                    ),
                    ("trace", Json::str(self.deploy.trace.to_string())),
                    (
                        "trace_buffer_spans",
                        Json::num(self.deploy.trace_buffer_spans as f64),
                    ),
                    ("on_failure", Json::str(self.deploy.on_failure.to_string())),
                    (
                        "connect_timeout_ms",
                        Json::num(self.deploy.connect_timeout_ms as f64),
                    ),
                    (
                        "connect_backoff_ms",
                        Json::num(self.deploy.connect_backoff_ms as f64),
                    ),
                    ("artifacts_dir", Json::str(self.deploy.artifacts_dir.clone())),
                ]),
            ),
            (
                "workload",
                Json::obj(vec![
                    ("name", Json::str(self.workload.name.clone())),
                    ("centers", Json::num(self.workload.centers as f64)),
                    (
                        "cpus_per_center",
                        Json::num(self.workload.cpus_per_center as f64),
                    ),
                    (
                        "jobs_per_center",
                        Json::num(self.workload.jobs_per_center as f64),
                    ),
                    (
                        "wan_bandwidth_mbps",
                        Json::num(self.workload.wan_bandwidth_mbps),
                    ),
                    ("wan_latency_s", Json::num(self.workload.wan_latency_s)),
                    ("transfer_mb", Json::num(self.workload.transfer_mb)),
                    (
                        "transfers_per_center",
                        Json::num(self.workload.transfers_per_center as f64),
                    ),
                    ("seed", Json::num(self.workload.seed as f64)),
                    (
                        "faithful_interrupts",
                        Json::Bool(self.workload.faithful_interrupts),
                    ),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        ScenarioConfig::default().validate().unwrap();
    }

    #[test]
    fn parse_full_config() {
        let text = r#"{
            "deploy": {"agents": 8, "workers": 2, "protocol": "eager", "exec": "step",
                       "placement": "rr", "backend": "native", "lookahead": 0.01},
            "workload": {"name": "t0t1", "centers": 6, "wan_bandwidth_mbps": 1000.0,
                         "seed": 42}
        }"#;
        let cfg = ScenarioConfig::from_json_text(text).unwrap();
        assert_eq!(cfg.deploy.agents, 8);
        assert_eq!(cfg.deploy.protocol, SyncProtocol::EagerNullMessages);
        assert_eq!(cfg.deploy.exec, ExecMode::PerTimestamp);
        assert_eq!(cfg.deploy.placement, PlacementPolicy::RoundRobin);
        assert_eq!(cfg.workload.centers, 6);
        assert_eq!(cfg.workload.seed, 42);
        assert_eq!(cfg.lookahead(), 0.01);
        // Unspecified fields fall back to defaults.
        assert_eq!(cfg.workload.cpus_per_center, 8);
    }

    #[test]
    fn json_roundtrip() {
        let cfg = ScenarioConfig::default();
        let text = cfg.to_json().to_string();
        let back = ScenarioConfig::from_json_text(&text).unwrap();
        assert_eq!(back.deploy.agents, cfg.deploy.agents);
        assert_eq!(back.workload.wan_bandwidth_mbps, cfg.workload.wan_bandwidth_mbps);
        assert_eq!(back.deploy.lookahead, cfg.deploy.lookahead);
        assert_eq!(back.deploy.exec, cfg.deploy.exec);
        assert_eq!(back.deploy.wire_batch, cfg.deploy.wire_batch);
        assert_eq!(back.deploy.max_frame_mib, cfg.deploy.max_frame_mib);
        assert_eq!(back.deploy.wire_codec, cfg.deploy.wire_codec);
        assert_eq!(
            back.deploy.writer_queue_frames,
            cfg.deploy.writer_queue_frames
        );
        assert_eq!(back.deploy.probe_fallback_ms, cfg.deploy.probe_fallback_ms);
        assert_eq!(back.deploy.window_budget, cfg.deploy.window_budget);
        assert_eq!(back.deploy.window_budget_min, cfg.deploy.window_budget_min);
        assert_eq!(back.deploy.window_budget_max, cfg.deploy.window_budget_max);
        assert_eq!(back.deploy.event_queue, cfg.deploy.event_queue);
    }

    #[test]
    fn event_queue_knob_parses_and_defaults() {
        use crate::engine::EventQueueKind;
        let cfg = ScenarioConfig::from_json_text("{}").unwrap();
        assert_eq!(cfg.deploy.event_queue, EventQueueKind::Heap);
        let cfg =
            ScenarioConfig::from_json_text(r#"{"deploy": {"event_queue": "ladder"}}"#).unwrap();
        assert_eq!(cfg.deploy.event_queue, EventQueueKind::Ladder);
        assert!(
            ScenarioConfig::from_json_text(r#"{"deploy": {"event_queue": "splay"}}"#).is_err()
        );
    }

    #[test]
    fn large_grid_workload_is_accepted() {
        let cfg = ScenarioConfig::from_json_text(
            r#"{"workload": {"name": "large_grid", "centers": 100}}"#,
        )
        .unwrap();
        assert_eq!(cfg.workload.name, "large_grid");
    }

    #[test]
    fn batching_knobs_parse_and_default() {
        // Defaults: batching on, 64 MiB frames, binary codec, 256-frame
        // writer queues, 2 ms probe fallback.
        let cfg = ScenarioConfig::from_json_text("{}").unwrap();
        assert!(cfg.deploy.wire_batch);
        assert_eq!(cfg.deploy.max_frame_mib, 64);
        assert_eq!(cfg.deploy.wire_codec, WireCodec::Binary);
        assert_eq!(cfg.deploy.writer_queue_frames, WriterQueue::Fixed(256));
        assert_eq!(cfg.deploy.probe_fallback_ms, 2);
        // Explicit overrides; a plain number still means a fixed depth.
        let cfg = ScenarioConfig::from_json_text(
            r#"{"deploy": {"wire_batch": false, "max_frame_mib": 8, "probe_fallback_ms": 10,
                           "wire_codec": "json", "writer_queue_frames": 4}}"#,
        )
        .unwrap();
        assert!(!cfg.deploy.wire_batch);
        assert_eq!(cfg.deploy.max_frame_mib, 8);
        assert_eq!(cfg.deploy.wire_codec, WireCodec::Json);
        assert_eq!(cfg.deploy.writer_queue_frames, WriterQueue::Fixed(4));
        assert_eq!(cfg.deploy.probe_fallback_ms, 10);
        // Policy strings: the adaptive depth and the explicit fixed form.
        let cfg = ScenarioConfig::from_json_text(
            r#"{"deploy": {"writer_queue_frames": "adaptive"}}"#,
        )
        .unwrap();
        assert_eq!(cfg.deploy.writer_queue_frames, WriterQueue::adaptive());
        let cfg = ScenarioConfig::from_json_text(
            r#"{"deploy": {"writer_queue_frames": "fixed(32)"}}"#,
        )
        .unwrap();
        assert_eq!(cfg.deploy.writer_queue_frames, WriterQueue::Fixed(32));
        assert!(
            ScenarioConfig::from_json_text(r#"{"deploy": {"writer_queue_frames": "turbo"}}"#)
                .is_err()
        );
    }

    #[test]
    fn window_budget_knobs_parse_and_default() {
        use crate::coordinator::adaptive::WindowBudgetMode;
        // Defaults: the historical fixed constant, clamps 256..=1M.
        let cfg = ScenarioConfig::from_json_text("{}").unwrap();
        assert_eq!(cfg.deploy.window_budget, WindowBudgetMode::Fixed(16_384));
        assert_eq!(cfg.deploy.window_budget_min, 256);
        assert_eq!(cfg.deploy.window_budget_max, 1 << 20);
        // Explicit adaptive with clamps.
        let cfg = ScenarioConfig::from_json_text(
            r#"{"deploy": {"window_budget": "adaptive", "window_budget_min": 8,
                           "window_budget_max": 4096}}"#,
        )
        .unwrap();
        assert_eq!(cfg.deploy.window_budget, WindowBudgetMode::Adaptive);
        assert_eq!(cfg.deploy.window_budget_min, 8);
        assert_eq!(cfg.deploy.window_budget_max, 4096);
        // Fixed(N) spelling and the unbounded form.
        let cfg =
            ScenarioConfig::from_json_text(r#"{"deploy": {"window_budget": "fixed(512)"}}"#)
                .unwrap();
        assert_eq!(cfg.deploy.window_budget, WindowBudgetMode::Fixed(512));
        let cfg =
            ScenarioConfig::from_json_text(r#"{"deploy": {"window_budget": "fixed(inf)"}}"#)
                .unwrap();
        assert_eq!(cfg.deploy.window_budget, WindowBudgetMode::Fixed(usize::MAX));
    }

    #[test]
    fn window_budget_knobs_reject_bad_clamps() {
        // min > max is a contradiction, zero budgets can never execute,
        // and garbage mode strings fail the parse — each with its own
        // actionable error.
        for (bad, needle) in [
            (
                r#"{"deploy": {"window_budget_min": 9, "window_budget_max": 8}}"#,
                "window_budget_min",
            ),
            (r#"{"deploy": {"window_budget_min": 0}}"#, "window_budget_min"),
            (r#"{"deploy": {"window_budget": "fixed(0)"}}"#, "window budget"),
            (r#"{"deploy": {"window_budget": "0"}}"#, "window budget"),
            (r#"{"deploy": {"window_budget": "auto"}}"#, "window budget"),
            (r#"{"deploy": {"window_budget": "fixed(-1)"}}"#, "window budget"),
        ] {
            let err = ScenarioConfig::from_json_text(bad)
                .err()
                .unwrap_or_else(|| panic!("accepted {bad}"));
            assert!(
                format!("{err:#}").contains(needle),
                "error for {bad} lacks '{needle}': {err:#}"
            );
        }
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(ScenarioConfig::from_json_text(r#"{"deploy": {"agents": 0}}"#).is_err());
        assert!(ScenarioConfig::from_json_text(r#"{"deploy": {"agents": 65}}"#).is_err());
        assert!(ScenarioConfig::from_json_text(r#"{"deploy": {"lookahead": -1}}"#).is_err());
        assert!(ScenarioConfig::from_json_text(r#"{"deploy": {"max_frame_mib": 0}}"#).is_err());
        assert!(
            ScenarioConfig::from_json_text(r#"{"deploy": {"wire_codec": "xml"}}"#).is_err()
        );
        assert!(
            ScenarioConfig::from_json_text(r#"{"deploy": {"writer_queue_frames": 0}}"#).is_err()
        );
        assert!(
            ScenarioConfig::from_json_text(r#"{"deploy": {"probe_fallback_ms": 0}}"#).is_err()
        );
        assert!(
            ScenarioConfig::from_json_text(r#"{"workload": {"name": "bogus"}}"#).is_err()
        );
        assert!(ScenarioConfig::from_json_text("not json").is_err());
        assert!(
            ScenarioConfig::from_json_text(r#"{"workload": {"wan_bandwidth_mbps": -5}}"#)
                .is_err()
        );
    }

    #[test]
    fn lookahead_defaults_to_wan_latency() {
        let cfg = ScenarioConfig::default();
        assert_eq!(cfg.lookahead(), cfg.workload.wan_latency_s);
    }

    #[test]
    fn robustness_knobs_parse_and_default() {
        // Defaults: checkpoints off, abort on failure, 5 s / 100 ms
        // connect retry budget.
        let cfg = ScenarioConfig::from_json_text("{}").unwrap();
        assert_eq!(cfg.deploy.checkpoint_windows, 0);
        assert_eq!(cfg.deploy.on_failure, OnFailure::Abort);
        assert_eq!(cfg.deploy.connect_timeout_ms, 5_000);
        assert_eq!(cfg.deploy.connect_backoff_ms, 100);
        let cfg = ScenarioConfig::from_json_text(
            r#"{"deploy": {"checkpoint_windows": 32, "on_failure": "restart",
                           "connect_timeout_ms": 800, "connect_backoff_ms": 25}}"#,
        )
        .unwrap();
        assert_eq!(cfg.deploy.checkpoint_windows, 32);
        assert_eq!(cfg.deploy.on_failure, OnFailure::Restart);
        assert_eq!(cfg.deploy.connect_timeout_ms, 800);
        assert_eq!(cfg.deploy.connect_backoff_ms, 25);
        // Round-trips through to_json.
        let back = ScenarioConfig::from_json_text(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back.deploy.checkpoint_windows, 32);
        assert_eq!(back.deploy.on_failure, OnFailure::Restart);
        // Rejections.
        for bad in [
            r#"{"deploy": {"on_failure": "retry"}}"#,
            r#"{"deploy": {"connect_timeout_ms": 0}}"#,
            r#"{"deploy": {"connect_backoff_ms": 0}}"#,
        ] {
            assert!(ScenarioConfig::from_json_text(bad).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn fault_plan_roundtrip_and_defaults() {
        let plan = FaultPlan::from_json_text(
            r#"{"seed": 7, "schedule": [
                {"kind": "kill_agent", "agent": 2, "at_window": 40},
                {"kind": "stall_heartbeat", "agent": 1, "at_window": 10,
                 "count": 5, "on_attempt": 2}
            ]}"#,
        )
        .unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.schedule.len(), 2);
        // Omitted count / on_attempt default to 1.
        assert_eq!(plan.schedule[0].count, 1);
        assert_eq!(plan.schedule[0].on_attempt, 1);
        assert_eq!(plan.schedule[0].kind, FaultKind::KillAgent);
        assert_eq!(plan.schedule[1].count, 5);
        assert_eq!(plan.schedule[1].on_attempt, 2);
        let back = FaultPlan::from_json_text(&plan.to_json().to_string()).unwrap();
        assert_eq!(back, plan);
        assert!(!plan.is_empty());
        assert!(FaultPlan::default().is_empty());
        // Unknown kinds and a missing schedule are rejected.
        assert!(FaultPlan::from_json_text(
            r#"{"schedule": [{"kind": "meteor", "agent": 1, "at_window": 0}]}"#
        )
        .is_err());
        assert!(FaultPlan::from_json_text(r#"{"seed": 3}"#).is_err());
    }
}
