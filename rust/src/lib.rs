//! # dsim — Distributed Simulation Framework for Large-Scale Distributed Systems
//!
//! A Rust + JAX/Pallas reproduction of *"Simulation Framework for Modeling
//! Large-Scale Distributed Systems"* (Dobre, Cristea, Legrand — CS.DC 2011):
//! a distributed discrete-event simulation (DDES) framework derived from the
//! MONARC regional-center simulation model.
//!
//! ## Architecture (three layers)
//!
//! * **Layer 3 (this crate)** — the paper's contribution: simulation agents
//!   hosting logical processes over a conservative synchronization engine
//!   (null-messages-by-demand), a performance-value placement scheduler, a
//!   replicated object space (JavaSpaces-like), lookup + monitoring services
//!   and the MONARC component library (CPUs, network with interrupt-based
//!   fair sharing, databases, mass storage, regional centers).
//!
//!   Execution is **safe-window batched**: each engine computes its
//!   conservative horizon (the minimum over peer LVT promises, each already
//!   embedding the sender's lookahead) once per scheduler turn and drains
//!   *every* event within it — events spawned mid-window included — in a
//!   single [`engine::Engine::advance_window`] call, emitting
//!   synchronization traffic once per window instead of once per
//!   timestamp.  Per-timestamp ordering semantics are preserved exactly,
//!   so results are bit-identical to the per-timestamp baseline
//!   ([`engine::ExecMode::PerTimestamp`], kept for equivalence testing)
//!   for any worker or agent count.
//! * **Layer 2 (python/compile/model.py, build-time)** — JAX graphs for the
//!   numeric hot spots: all-pairs-shortest-path placement scoring and
//!   max-min fair bandwidth allocation.
//! * **Layer 1 (python/compile/kernels/, build-time)** — Pallas kernels
//!   (tiled min-plus matmul; water-filling sweep) called by L2.
//!
//! L2/L1 are AOT-lowered once to HLO text (`make artifacts`) and executed
//! from Rust via the PJRT C API ([`runtime`]); Python never runs at
//! simulation time.
//!
//! ## Quickstart
//!
//! ```no_run
//! use dsim::prelude::*;
//!
//! let scenario = dsim::workload::two_center_demo();
//! let report = Deployment::in_process(2)
//!     .run(scenario)
//!     .expect("simulation failed");
//! println!("completed {} jobs", report.jobs_completed);
//! ```

pub mod bench;
pub mod components;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod lookup;
pub mod metrics;
pub mod model;
pub mod monitor;
pub mod runtime;
pub mod scenario;
pub mod space;
pub mod testkit;
pub mod trace;
pub mod transport;
pub mod util;
pub mod workload;

/// Convenience re-exports for the common user-facing API surface.
pub mod prelude {
    pub use crate::components::RegionalCenter;
    pub use crate::config::ScenarioConfig;
    pub use crate::coordinator::{Deployment, RunReport};
    pub use crate::engine::{ExecMode, SimTime, SyncProtocol};
    pub use crate::metrics::ResultPool;
    pub use crate::model::Scenario;
    pub use crate::runtime::ComputeBackend;
    pub use crate::scenario::CompiledScenario;
    pub use crate::trace::{CriticalPath, TraceMode};
    pub use crate::transport::WireCodec;
}
