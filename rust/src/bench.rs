//! Tiny measurement harness for the `harness = false` benches (the offline
//! snapshot has no criterion).
//!
//! [`Bench`] runs a closure with warmup + repeated timed iterations and
//! prints a criterion-like one-line summary (median, mean, min/max).  The
//! paper-reproduction benches additionally print labeled data rows
//! (`row!`-style via [`Bench::report_row`]) that EXPERIMENTS.md quotes
//! directly.

use std::time::{Duration, Instant};

use crate::metrics::{summarize, Summary};

/// One benchmark context.
pub struct Bench {
    name: String,
    warmup: usize,
    iters: usize,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        Bench {
            name: name.to_string(),
            warmup: 1,
            iters: 5,
        }
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    pub fn iters(mut self, n: usize) -> Self {
        self.iters = n;
        self
    }

    /// Run `f` (warmup + timed); returns the per-iteration wall times and
    /// prints a summary line.
    pub fn run<F: FnMut()>(&self, mut f: F) -> Vec<Duration> {
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
        }
        let secs: Vec<f64> = times.iter().map(|d| d.as_secs_f64()).collect();
        if let Some(s) = summarize(&secs) {
            println!(
                "bench {:<40} median {:>10.4}s  mean {:>10.4}s  min {:>10.4}s  max {:>10.4}s  (n={})",
                self.name, s.p50, s.mean, s.min, s.max, s.n
            );
        }
        times
    }

    /// Summary of a run's timings in seconds.
    pub fn summary(times: &[Duration]) -> Option<Summary> {
        summarize(&times.iter().map(|d| d.as_secs_f64()).collect::<Vec<_>>())
    }
}

/// Peak resident set size of this process in bytes (Linux `VmHWM`).
/// Process-monotone: it never decreases, so callers comparing scales
/// should measure in increasing-size order.
///
/// Returns **0 where `/proc` is unavailable (non-Linux)** — that zero is
/// "no measurement", not "zero bytes".  Consumers deriving ratios from
/// it (bytes/LP and the like) must treat a 0 reading as absent rather
/// than reporting a ratio of 0; the bench binaries print an explicit
/// "rss unavailable" note in that case so rows are never mistaken for
/// real measurements.
pub fn peak_rss_bytes() -> u64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("VmHWM:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|kb| kb.parse::<u64>().ok())
        })
        .map(|kb| kb * 1024)
        .unwrap_or(0)
}

/// Print a labeled data row in a stable, grep-able format:
/// `ROW <table> | k1=v1 k2=v2 ...`
pub fn report_row(table: &str, fields: &[(&str, String)]) {
    let body: Vec<String> = fields.iter().map(|(k, v)| format!("{k}={v}")).collect();
    println!("ROW {table} | {}", body.join(" "));
}

/// Format seconds with fixed precision for rows.
pub fn fmt_s(x: f64) -> String {
    format!("{x:.4}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0;
        let times = Bench::new("noop").warmup(2).iters(3).run(|| {
            count += 1;
        });
        assert_eq!(count, 5);
        assert_eq!(times.len(), 3);
        assert!(Bench::summary(&times).is_some());
    }
}
